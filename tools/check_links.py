"""Intra-repo Markdown link checker — the CI docs gate.

Scans README.md and docs/*.md (or any paths passed as arguments) for
Markdown links and verifies that every relative target resolves to a file
or directory in the repo.  External schemes (http/https/mailto) and
pure-anchor links are skipped; a `#fragment` suffix on a relative link is
stripped before the existence check.

    python tools/check_links.py            # default file set
    python tools/check_links.py docs/*.md  # explicit
"""
from __future__ import annotations

import pathlib
import re
import sys
import urllib.parse
from typing import List, Tuple

# [text](target) — target up to ')' with an optional "title", optionally
# <>-wrapped, spaces allowed; also matches images ![alt](target).
# Reference-style links are rare here and skipped.
_LINK_RE = re.compile(
    r"\[[^\]]*\]\(\s*<?([^)>\"]+?)>?(?:\s+\"[^\"]*\")?\s*\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def iter_links(text: str) -> List[Tuple[int, str]]:
    """Yield (1-based line number, raw target) for every Markdown link."""
    out = []
    for i, line in enumerate(text.splitlines(), start=1):
        for match in _LINK_RE.finditer(line):
            out.append((i, match.group(1)))
    return out


def broken_links(path: pathlib.Path,
                 root: pathlib.Path) -> List[Tuple[int, str]]:
    """Return (line, target) for every intra-repo link that doesn't resolve.

    Relative targets resolve against the Markdown file's own directory;
    absolute-style targets (leading ``/``) resolve against the repo root.
    """
    out = []
    text = path.read_text(encoding="utf-8")
    for line, target in iter_links(text):
        target = target.strip()
        if target.startswith(_SKIP_PREFIXES):
            continue
        rel = urllib.parse.unquote(target.split("#", 1)[0])
        if not rel:
            continue
        base = root if rel.startswith("/") else path.parent
        candidate = (base / rel.lstrip("/")).resolve()
        if not candidate.exists():
            out.append((line, target))
    return out


def default_files(root: pathlib.Path) -> List[pathlib.Path]:
    """README.md plus every Markdown file under docs/."""
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def main(argv: List[str]) -> int:
    """Check the given (or default) files; print breaks; return exit code."""
    root = pathlib.Path(__file__).resolve().parent.parent
    files = ([pathlib.Path(a) for a in argv] if argv
             else default_files(root))
    total_broken = 0
    for f in files:
        f = f.resolve()
        name = f.relative_to(root) if f.is_relative_to(root) else f
        if not f.is_file():
            print(f"{name}: no such file")
            total_broken += 1
            continue
        for line, target in broken_links(f, root):
            print(f"{name}:{line}: broken link -> {target}")
            total_broken += 1
    if total_broken:
        print(f"{total_broken} broken intra-repo link(s)")
        return 1
    print(f"checked {len(files)} file(s): all intra-repo links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
