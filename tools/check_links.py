"""Intra-repo Markdown link + anchor checker — the CI docs gate.

Scans README.md and docs/*.md (or any paths passed as arguments) for
Markdown links and verifies that

  * every relative target resolves to a file or directory in the repo;
  * every ``#fragment`` — pure-anchor (``#usage``) or suffixed on a
    relative Markdown target (``roofline.md#ceilings``) — matches a
    heading anchor GitHub would render for the target file (lowercased,
    punctuation stripped, spaces to hyphens, ``-N`` suffixes for
    duplicate headings).

External schemes (http/https/mailto) are skipped.

    python tools/check_links.py            # default file set
    python tools/check_links.py docs/*.md  # explicit
"""
from __future__ import annotations

import pathlib
import re
import sys
import urllib.parse
from typing import List, Set, Tuple

# [text](target) — target up to ')' with an optional "title", optionally
# <>-wrapped, spaces allowed; also matches images ![alt](target).
# Reference-style links are rare here and skipped.
_LINK_RE = re.compile(
    r"\[[^\]]*\]\(\s*<?([^)>\"]+?)>?(?:\s+\"[^\"]*\")?\s*\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.+?)\s*#*\s*$")


def iter_links(text: str) -> List[Tuple[int, str]]:
    """Yield (1-based line number, raw target) for every Markdown link."""
    out = []
    for i, line in enumerate(text.splitlines(), start=1):
        for match in _LINK_RE.finditer(line):
            out.append((i, match.group(1)))
    return out


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for one heading's text.

    Inline markup is unwrapped (code spans, emphasis, link text), then:
    lowercase, drop everything but word chars / hyphens / spaces, spaces
    become hyphens.
    """
    text = re.sub(r"`([^`]*)`", r"\1", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    text = re.sub(r"[*_]", "", text)
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_anchors(text: str) -> Set[str]:
    """Every anchor GitHub renders for ``text``'s ATX headings.

    Duplicate headings get ``-1``, ``-2``, ... suffixes, matching
    GitHub's disambiguation.  Headings inside fenced code blocks are
    ignored.
    """
    anchors: Set[str] = set()
    seen: dict = {}
    in_fence = False
    for line in text.splitlines():
        if line.lstrip().startswith(("```", "~~~")):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        match = _HEADING_RE.match(line)
        if not match:
            continue
        slug = slugify(match.group(2))
        n = seen.get(slug, 0)
        anchors.add(slug if n == 0 else f"{slug}-{n}")
        seen[slug] = n + 1
    return anchors


def broken_links(path: pathlib.Path,
                 root: pathlib.Path) -> List[Tuple[int, str]]:
    """Return (line, target) for every intra-repo link that doesn't resolve.

    Relative targets resolve against the Markdown file's own directory;
    absolute-style targets (leading ``/``) resolve against the repo root.
    A ``#fragment`` is checked against the target Markdown file's heading
    anchors (the current file for pure-anchor links).
    """
    out = []
    text = path.read_text(encoding="utf-8")
    own_anchors = None
    for line, target in iter_links(text):
        target = target.strip()
        if target.startswith(_SKIP_PREFIXES):
            continue
        rel, _, frag = target.partition("#")
        rel = urllib.parse.unquote(rel)
        frag = urllib.parse.unquote(frag)
        if not rel:
            # Pure anchor: must match a heading in this file.
            if own_anchors is None:
                own_anchors = heading_anchors(text)
            if frag and frag not in own_anchors:
                out.append((line, target))
            continue
        base = root if rel.startswith("/") else path.parent
        candidate = (base / rel.lstrip("/")).resolve()
        if not candidate.exists():
            out.append((line, target))
            continue
        if frag and candidate.suffix.lower() == ".md" and candidate.is_file():
            if frag not in heading_anchors(
                    candidate.read_text(encoding="utf-8")):
                out.append((line, target))
    return out


def default_files(root: pathlib.Path) -> List[pathlib.Path]:
    """README.md plus every Markdown file under docs/."""
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def main(argv: List[str]) -> int:
    """Check the given (or default) files; print breaks; return exit code."""
    root = pathlib.Path(__file__).resolve().parent.parent
    files = ([pathlib.Path(a) for a in argv] if argv
             else default_files(root))
    total_broken = 0
    for f in files:
        f = f.resolve()
        name = f.relative_to(root) if f.is_relative_to(root) else f
        if not f.is_file():
            print(f"{name}: no such file")
            total_broken += 1
            continue
        for line, target in broken_links(f, root):
            kind = "anchor" if "#" in target else "link"
            print(f"{name}:{line}: broken {kind} -> {target}")
            total_broken += 1
    if total_broken:
        print(f"{total_broken} broken intra-repo link(s)/anchor(s)")
        return 1
    print(f"checked {len(files)} file(s): all intra-repo links and "
          f"anchors resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
