"""Harvest the corpus into a fitted dispatch tree, then audit it.

Closes the SpChar loop (arXiv 2304.06944) over the matrix corpus:

  1. **Sweep** — for every corpus matrix (vendored samples by default,
     or ``--corpus-root`` / ``$REPRO_CORPUS_DIR``) and every dense width
     in ``--d``, time each policy-eligible format through the real
     dispatcher executor and record ``(StructureReport features,
     per-format measured GFLOP/s)`` rows.
  2. **Fit** — train the pure-NumPy decision tree
     (``repro.data.dtree.DecisionTree``) on (features -> measured-best
     format) and persist it beside the calibration store as
     ``dispatch_tree-<backend>.json`` (plus a copy in ``--out-dir`` for
     CI artifact upload).
  3. **Audit** — replan every (matrix, d) pair analytic-only vs
     tree-assisted and emit an agreement CSV
     (``matrix, impl=tree_vs_analytic, d, agreement, never_worse``)
     that ``tools/perf_trend.py --metric agreement`` can trend across
     nightly runs, and check the gated claim: the tree-assisted choice's
     *measured* GFLOP/s is never below ``--claim-factor`` (default
     0.95) of the analytic-only choice's.  ``--enforce`` turns a claim
     failure into a non-zero exit.

Run from the repo root:

    PYTHONPATH=src python tools/harvest_dispatch.py \
        --out-dir benchmarks/out/harvest --enforce
"""
from __future__ import annotations

import argparse
import csv
import json
import pathlib
import sys
import time
from typing import Dict, List

HARVEST_CSV = "harvest_dispatch.csv"
AGREEMENT_CSV = "dispatch_agreement.csv"
TREE_JSON = "dispatch_tree.json"


def _time_exec(run, b, repeats: int) -> float:
    """Best-of-``repeats`` seconds for ``run(b)`` (first call warms jit)."""
    import jax
    jax.block_until_ready(run(b))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(run(b))
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(entries, widths: List[int], repeats: int, backend: str):
    """Measure every (matrix, d, eligible format) cell.

    Returns ``(rows, samples)``: CSV-ready measurement rows and the
    training samples ``{"features": vec, "label": best_format,
    "matrix": name, "d": d}``.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core.classify import classify
    from repro.data.dtree import features_from_report
    from repro.sparse.dispatch import FORMATS, Dispatcher

    disp = Dispatcher(backend=backend, tree=False)
    rows, samples = [], []
    for entry in entries:
        m = entry.load()
        report = classify(m)
        rng = np.random.default_rng(0)
        for d in widths:
            b = jnp.asarray(rng.standard_normal((m.n, d)),
                            dtype=jnp.float32)
            flops = 2.0 * m.nnz * d
            measured: Dict[str, float] = {}
            for f in FORMATS:
                try:
                    plan = disp.plan(m, d, strategy=f)
                except ValueError:
                    continue            # policy-ineligible: no sample
                secs = _time_exec(disp.executor(m, plan), b, repeats)
                gflops = flops / secs / 1e9
                measured[f] = gflops
                rows.append({"matrix": entry.name, "group": entry.group,
                             "impl": f, "d": d, "n": m.n, "nnz": m.nnz,
                             "gflops": f"{gflops:.4f}"})
            best = max(measured, key=measured.get)
            samples.append({
                "features": features_from_report(report, d),
                "label": best, "matrix": entry.name, "d": d,
                "measured": measured,
            })
            print(f"  {entry.name:28s} d={d:4d} best={best:8s} "
                  f"({measured[best]:.2f} GF/s, "
                  f"{len(measured)}/{len(FORMATS)} eligible)")
    return rows, samples


def audit(entries, samples, tree, margin: float, backend: str,
          claim_factor: float):
    """Tree-assisted vs analytic-only dispatch over the harvested pairs.

    Returns ``(rows, agreement_rate, claim_ok)``; a pair passes the
    claim when the tree-assisted choice's measured GFLOP/s is at least
    ``claim_factor`` times the analytic-only choice's.
    """
    from repro.sparse.dispatch import Dispatcher

    analytic = Dispatcher(backend=backend, tree=False)
    assisted = Dispatcher(backend=backend, tree=tree, tree_margin=margin)
    by_name = {e.name: e for e in entries}
    rows, agree, claim_ok = [], 0, True
    for s in samples:
        m = by_name[s["matrix"]].load()
        d = s["d"]
        a = analytic.plan(m, d).chosen
        t_plan = assisted.plan(m, d)
        t = t_plan.chosen
        same = int(a == t)
        agree += same
        # The never-worse claim compares *measured* throughput of the
        # two choices (both were timed in the sweep; a policy-eligible
        # plan choice is always a measured format).
        never_worse = int(
            s["measured"].get(t, 0.0)
            >= claim_factor * s["measured"].get(a, 0.0))
        claim_ok &= bool(never_worse)
        rows.append({"matrix": s["matrix"], "impl": "tree_vs_analytic",
                     "d": d, "agreement": same,
                     "never_worse": never_worse,
                     "analytic": a, "tree": t,
                     "decision_source": t_plan.decision_source})
    rate = agree / max(len(samples), 1)
    return rows, rate, claim_ok


def _write_csv(path: pathlib.Path, rows: List[dict]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w", newline="", encoding="utf-8") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)


def main(argv: List[str]) -> int:
    """Sweep, fit, persist, audit; return the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--corpus-root", default=None,
                    help="corpus directory (default: $REPRO_CORPUS_DIR "
                         "or the vendored samples)")
    ap.add_argument("--out-dir", default="benchmarks/out/harvest",
                    help="where the CSVs + fitted-tree JSON artifact go")
    ap.add_argument("--d", type=int, nargs="+", default=[32, 128],
                    help="dense operand widths to sweep")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per cell (best-of)")
    ap.add_argument("--backend", default="jax",
                    choices=["jax", "pallas"])
    ap.add_argument("--max-depth", type=int, default=4)
    ap.add_argument("--min-leaf", type=int, default=2)
    ap.add_argument("--margin", type=float, default=0.10,
                    help="tree_margin used for the agreement audit")
    ap.add_argument("--claim-factor", type=float, default=0.95,
                    help="tree-assisted measured GFLOP/s must be >= this "
                         "fraction of analytic-only's")
    ap.add_argument("--no-store", action="store_true",
                    help="skip persisting the tree to the calibration "
                         "root (the --out-dir artifact copy still "
                         "happens)")
    ap.add_argument("--enforce", action="store_true",
                    help="exit 1 when the never-worse claim fails")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.data import corpus
    from repro.data.dtree import DecisionTree, DispatchTreeStore

    entries = corpus.corpus_entries(args.corpus_root)
    if not entries:
        print("harvest: corpus is empty", file=sys.stderr)
        return 1
    print(f"harvest: {len(entries)} matrices x d={args.d} "
          f"({args.backend} backend)")

    rows, samples = sweep(entries, args.d, args.repeats, args.backend)
    out = pathlib.Path(args.out_dir)
    _write_csv(out / HARVEST_CSV, rows)

    x = np.stack([s["features"] for s in samples])
    y = [s["label"] for s in samples]
    tree = DecisionTree(max_depth=args.max_depth,
                        min_leaf=args.min_leaf).fit(x, y)
    meta = {"rows": len(samples), "widths": args.d,
            "matrices": sorted({s["matrix"] for s in samples})}
    (out / TREE_JSON).write_text(json.dumps(
        {"tree": tree.to_json(), "backend": args.backend, "meta": meta},
        indent=2), encoding="utf-8")
    if not args.no_store:
        path = DispatchTreeStore().save(tree, args.backend, meta=meta)
        print(f"harvest: tree persisted to {path}")

    arows, rate, claim_ok = audit(entries, samples, tree, args.margin,
                                  args.backend, args.claim_factor)
    _write_csv(out / AGREEMENT_CSV, arows)
    print(f"harvest: fitted depth<={args.max_depth} tree "
          f"({tree.fingerprint()}) on {len(samples)} samples")
    print(f"harvest: tree/analytic agreement {rate:.0%}; never-worse "
          f"claim ({args.claim_factor}x measured) "
          f"{'PASS' if claim_ok else 'FAIL'}")
    for r in arows:
        if not r["never_worse"]:
            print(f"  CLAIM FAIL {r['matrix']} d={r['d']}: "
                  f"tree={r['tree']} analytic={r['analytic']}")
    if args.enforce and not claim_ok:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
