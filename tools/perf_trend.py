"""Perf trend: diff the current smoke_spmm.csv against the previous run's.

CI uploads ``benchmarks/out/smoke_spmm.csv`` on every run
(``.github/workflows/ci.yml``); this tool compares the current CSV
against the artifact downloaded from the last successful run and flags
GFLOP/s regressions beyond a threshold (default 10%).

The gate is a *soft warn* by default: regressions print as GitHub
``::warning::`` annotations and the exit code stays 0, because single
cells on shared CI hosts swing well beyond 10% between identical runs
(the same wall-clock noise the claim checks aggregate around).  Pass
``--strict`` to turn regressions into a non-zero exit (release branches
/ manual bisection), and widen the baseline to a *trend window* by
passing several artifacts — the baseline is then the per-cell median of
the last N runs, which is what makes ``--strict`` usable at all: one
lucky previous run no longer fails every following one.

    python tools/perf_trend.py \
        --previous run1/smoke_spmm.csv run2/smoke_spmm.csv \
                   run3/smoke_spmm.csv \
        --current benchmarks/out/smoke_spmm.csv --strict

CSV schema: ``benchmarks.spmm_suite.CSV_HEADER`` (streamed rows append
with the mode+reuse encoded in the impl column, e.g. ``stream_r8``;
sharded rows with the tier, e.g. ``shard8_all_gather``).  The serving
engine's latency CSV (``benchmarks.stream.ENGINE_CSV_HEADER``) trends
through the same tool with ``--metric goodput_rps`` — any
higher-is-better column keyed by (matrix, impl, d) works:

    python tools/perf_trend.py --metric goodput_rps \
        --previous run1/engine_smoke.csv run2/engine_smoke.csv \
        --current benchmarks/out/engine_smoke.csv
"""
from __future__ import annotations

import argparse
import csv
import pathlib
import statistics
import sys
from typing import Dict, List, Tuple

Key = Tuple[str, str, str, str]     # (matrix, impl, d, dtype)


def parse_csv(path: pathlib.Path,
              metric: str = "gflops") -> Dict[Key, float]:
    """Read one benchmark CSV into ``(matrix, impl, d, dtype) -> metric``.

    ``dtype`` is the storage-precision token column; CSVs written before
    it existed key as ``f32i32`` (what those cells actually ran at), so
    a bf16 lane's cells never trend against fp32 baselines.
    """
    rows: Dict[Key, float] = {}
    with open(path, newline="", encoding="utf-8") as f:
        for rec in csv.DictReader(f):
            try:
                key = (rec["matrix"], rec["impl"], rec["d"],
                       rec.get("dtype") or "f32i32")
                rows[key] = float(rec[metric])
            except (KeyError, TypeError, ValueError):
                continue            # malformed/partial row: skip, don't die
    return rows


def baseline_window(paths: List[pathlib.Path],
                    metric: str = "gflops") -> Dict[Key, float]:
    """Per-cell median metric value across a window of baseline CSVs.

    Each cell's baseline is the median over the artifacts that contain
    it (new cells appear in fewer files while the window fills up).
    Missing files are skipped — artifact fetches fail routinely — so the
    window degrades gracefully down to single-file behaviour.
    """
    samples: Dict[Key, List[float]] = {}
    for path in paths:
        if not path.is_file():
            print(f"perf-trend: baseline {path} missing, skipped")
            continue
        for key, gf in parse_csv(path, metric).items():
            samples.setdefault(key, []).append(gf)
    return {k: statistics.median(v) for k, v in samples.items()}


def compare(prev: Dict[Key, float], cur: Dict[Key, float],
            threshold: float) -> List[Tuple[Key, float, float, float]]:
    """Cells regressing by more than ``threshold`` (fractional drop).

    Returns ``(key, prev_value, cur_value, drop)`` sorted by worst
    drop first; only keys present in both CSVs are compared.
    """
    out = []
    for key in sorted(prev.keys() & cur.keys()):
        p, c = prev[key], cur[key]
        if p <= 0:
            continue
        drop = (p - c) / p
        if drop > threshold:
            out.append((key, p, c, drop))
    return sorted(out, key=lambda r: -r[3])


def main(argv: List[str]) -> int:
    """Compare CSVs, print the trend report, return the exit code."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--previous", required=True, nargs="+",
                    help="baseline CSV(s): pass several recent artifacts "
                         "and each cell compares against its median over "
                         "the window (one path = plain last-run diff)")
    ap.add_argument("--current", required=True,
                    help="this run's CSV")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional GFLOP/s drop that counts as a "
                         "regression (default 0.10)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regressions instead of soft-warning")
    ap.add_argument("--metric", default="gflops",
                    help="CSV column to trend (higher is better); "
                         "'gflops' for the SpMM CSVs, 'goodput_rps' for "
                         "the engine latency CSV")
    args = ap.parse_args(argv)

    prev = baseline_window([pathlib.Path(p) for p in args.previous],
                           args.metric)
    if not prev:
        print("perf-trend: no readable baseline CSVs (first run, or "
              "artifact fetch failed); nothing to compare")
        return 0
    cur_path = pathlib.Path(args.current)
    if not cur_path.is_file():
        print(f"perf-trend: current CSV missing at {cur_path}")
        return 1

    cur = parse_csv(cur_path, args.metric)
    shared = prev.keys() & cur.keys()
    if not shared:
        print("perf-trend: no comparable cells between baseline and "
              "current (schema or suite changed); nothing to compare")
        return 0

    regressions = compare(prev, cur, args.threshold)
    improved = sum(1 for k in shared
                   if prev[k] > 0 and (cur[k] - prev[k]) / prev[k]
                   > args.threshold)
    print(f"perf-trend: {len(shared)} comparable cells, "
          f"{len(regressions)} regressed >{args.threshold:.0%}, "
          f"{improved} improved >{args.threshold:.0%}")
    for (matrix, impl, d, dtype), p, c, drop in regressions:
        msg = (f"{matrix}/{impl}/d={d}/{dtype}: {p:.3f} -> {c:.3f} "
               f"{args.metric} ({drop:.0%} drop)")
        # GitHub annotation so the warning surfaces on the PR checks page.
        print(f"::warning title=SpMM perf regression::{msg}")
        print(f"  REGRESSION {msg}")
    if regressions and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
