"""Regenerate the vendored corpus sample set (deterministic, offline).

SuiteSparse/DLMC are unavailable offline, so the vendored corpus under
``src/repro/data/corpus_samples/`` is a deterministic stand-in: small
matrices in each of the paper's four structure groups, written through
the real ``.smtx`` / ``.mtx`` serializers so the loaders, the classifier
golden tests, and the differential harness exercise the exact file
formats a downloaded corpus would arrive in.  Both formats appear in
every run so neither loader can rot unnoticed.

Run from the repo root to refresh the files (they are committed):

    PYTHONPATH=src python tools/make_corpus_samples.py
"""
from __future__ import annotations

import sys

import numpy as np


def samples():
    """The vendored set: (filename, COOMatrix) in all four groups."""
    from repro.core import patterns
    from repro.data import corpus

    def transpose(m):
        # Column-hub regression fixture (the classify() row-degree bug):
        # re-sorted row-major through the loader finalizer.
        return corpus._finalize_loaded(
            m.n, m.cols.astype(np.int64), m.rows.astype(np.int64),
            m.vals, m.pattern, dict(m.meta))

    return [
        ("random__er_256_8.smtx",
         patterns.erdos_renyi(256, 8, seed=1)),
        ("random__er_192_12.mtx",
         patterns.erdos_renyi(192, 12, seed=2)),
        ("diagonal__tridiag_256.smtx",
         patterns.banded(256, 2, fill=1.0, seed=4)),
        ("diagonal__band_224_5.mtx",
         patterns.banded(224, 5, fill=0.85, seed=5)),
        ("blocked__fem_256_t32.smtx",
         patterns.blocked(256, t=32, num_blocks=16, nnz_per_block=256,
                          seed=6)),
        ("blocked__mesh_256_t32.mtx",
         patterns.blocked(256, t=32, num_blocks=24, nnz_per_block=40,
                          seed=6)),
        ("scale_free__hub_256_21.smtx",
         patterns.scale_free(256, 8, alpha=2.1, seed=8)),
        # The transpose of a hub graph: uniform row degrees, heavy
        # column tail — the matrix that exposed the row-only classifier.
        ("scale_free__colhub_192.mtx",
         transpose(patterns.scale_free(192, 6, alpha=2.3, seed=9))),
    ]


def main() -> int:
    """Write the sample files and verify each classifies into its group."""
    from repro.core.classify import classify
    from repro.data import corpus

    corpus.SAMPLES_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for filename, m in samples():
        group = filename.split("__", 1)[0]
        path = corpus.SAMPLES_DIR / filename
        if path.suffix == ".smtx":
            corpus.write_smtx(m, path)
        else:
            corpus.write_mtx(m, path)
        loaded = corpus.load_matrix(path)
        regime = classify(loaded).regime
        status = "ok" if regime == group else "MISCLASSIFIED"
        if regime != group:
            failures.append((filename, regime))
        print(f"{filename:32s} n={loaded.n:4d} nnz={loaded.nnz:6d} "
              f"-> {regime:10s} [{status}]")
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        return 1
    print(f"wrote {len(samples())} samples to {corpus.SAMPLES_DIR}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
