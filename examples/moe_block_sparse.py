"""MoE expert FFN as block-diagonal BCSR SpMM (the paper's blocked regime).

Routes a token batch with a top-k router, sorts tokens by expert into
128-row blocks, runs the grouped_matmul Pallas kernel, checks it against
the one-hot oracle, and prints the sparsity-aware roofline placement.

    PYTHONPATH=src python examples/moe_block_sparse.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels
from repro.kernels import ref, registry

E, K_DIM, N_DIM, TOKENS, TOPK, BM = 8, 64, 128, 1024, 2, 128

rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(TOKENS, K_DIM)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(E, K_DIM, N_DIM)).astype(np.float32))
router = jnp.asarray(rng.normal(size=(K_DIM, E)).astype(np.float32))

# Route and sort tokens by expert (MegaBlocks-style block alignment).
probs = jax.nn.softmax(x @ router, axis=-1)
expert = jnp.argmax(probs, axis=-1)            # top-1 for the demo
order = jnp.argsort(expert)
x_sorted = x[order]
# Block-align: pad each expert segment up to a BM multiple.
counts = np.bincount(np.asarray(expert), minlength=E)
blocks, gids, rows = [], [], []
for e in range(E):
    seg = np.asarray(order)[np.asarray(expert)[np.asarray(order)] == e]
    n_blocks = max(1, -(-len(seg) // BM))
    padded = np.zeros((n_blocks * BM, K_DIM), np.float32)
    padded[:len(seg)] = np.asarray(x)[seg]
    blocks.append(padded)
    gids.extend([e] * n_blocks)
    rows.append(seg)
xb = jnp.asarray(np.concatenate(blocks))
gid = jnp.asarray(np.asarray(gids, np.int32))

spec = registry.get("grouped", "pallas")
out = spec.bind((w, gid, BM, 64, 128), registry.KernelContext())(xb)
expect = ref.grouped_matmul_ref(xb, w, gid, bm=BM)
np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                           rtol=2e-3, atol=2e-3)
roof = kernels.grouped_matmul_roofline(xb.shape[0], K_DIM, N_DIM, E)
print(f"tokens routed to {E} experts; buffer {xb.shape[0]} rows "
      f"({xb.shape[0] - TOKENS} block padding)")
print(f"kernel allclose OK; AI={roof.ai:.1f} FLOP/B, "
      f"MXU utilization={roof.mxu_utilization:.2f}, "
      f"attainable {roof.attainable_flops_per_s / 1e12:.0f} TF/s on v5e")
print("(cf. paper Eq. 4: block-diagonal dispatch => z = t, the best case "
      "of the blocked-sparsity regime)")
