"""Quickstart: the paper's core loop in ~40 lines.

Generate one matrix per sparsity regime, classify its structure, evaluate
the matching sparsity-aware AI model, and compare the predicted roofline
ceiling with measured SpMM throughput.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sparse
from repro.core import banded, blocked, classify, erdos_renyi, scale_free

BETA = 8.5e9      # measure with `python -m benchmarks.run` (STREAM triad)
N, D = 2 ** 14, 16

matrices = {
    "er (random)": erdos_renyi(N, 10, seed=0),
    "ideal_diagonal": banded(N, 1, seed=1),
    "fem blocks": blocked(N, t=32, num_blocks=N // 16, nnz_per_block=320,
                          seed=2),
    "powerlaw": scale_free(N, 16, alpha=2.2, seed=3),
}

b = jnp.asarray(np.random.default_rng(0).normal(size=(N, D)), jnp.float32)
print(f"{'matrix':16s} {'regime':11s} {'AI':>6s} {'pred GF/s':>9s} "
      f"{'meas GF/s':>9s} {'frac':>5s}")
for name, m in matrices.items():
    report = classify(m)
    ai = report.traffic(D, sizeof_val=4).ai
    csr = sparse.coo_to_csr(m)
    jax.block_until_ready(sparse.csr_spmm(csr, b))   # compile
    t0 = time.perf_counter()
    jax.block_until_ready(sparse.csr_spmm(csr, b))
    dt = time.perf_counter() - t0
    gf = 2 * m.nnz * D / dt / 1e9
    pred = BETA * ai / 1e9
    print(f"{name:16s} {report.regime:11s} {ai:6.3f} {pred:9.2f} "
          f"{gf:9.2f} {gf / pred:5.2f}")
