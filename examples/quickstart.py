"""Quickstart: the paper's core loop in ~40 lines.

Generate one matrix per sparsity regime and let the structure-aware
dispatcher do the paper's work: classify the structure, evaluate every
candidate format's sparsity-aware roofline, pick the (format, kernel)
pair, and run it — then compare the prediction with measured throughput.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sparse
from repro.core import banded, blocked, erdos_renyi, scale_free

N, D = 2 ** 14, 16

matrices = {
    "er (random)": erdos_renyi(N, 10, seed=0),
    "ideal_diagonal": banded(N, 1, seed=1),
    "fem blocks": blocked(N, t=32, num_blocks=N // 16, nnz_per_block=320,
                          seed=2),
    "powerlaw": scale_free(N, 16, alpha=2.2, seed=3),
}

b = jnp.asarray(np.random.default_rng(0).normal(size=(N, D)), jnp.float32)
print(f"{'matrix':16s} {'regime':11s} {'chosen':7s} {'AI':>6s} "
      f"{'pred GF/s':>9s} {'meas GF/s':>9s} {'frac':>5s}")
for name, m in matrices.items():
    plan = sparse.plan_spmm(m, D)                 # inspectable decision
    jax.block_until_ready(sparse.spmm(m, b))      # convert + compile
    t0 = time.perf_counter()
    jax.block_until_ready(sparse.spmm(m, b, strategy="auto"))
    dt = time.perf_counter() - t0
    gf = 2 * m.nnz * D / dt / 1e9
    best = plan.candidate(plan.chosen)
    print(f"{name:16s} {plan.regime:11s} {plan.chosen:7s} {best.ai:6.3f} "
          f"{best.predicted_gflops:9.2f} {gf:9.2f} "
          f"{gf / best.predicted_gflops:5.2f}")

# The full audit trail for one decision: per-candidate predictions,
# conversion amortization, and policy skip reasons.
print()
print(sparse.plan_spmm(matrices["powerlaw"], D).summary())
