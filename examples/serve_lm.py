"""Batched serving example: prefill + greedy decode with KV/recurrent
caches on three architecture families (attention, hybrid, SSM), then the
block-sparse serving path — the MoE expert-dispatch SpMM served through a
persistent ``sparse.plan`` (plan once, execute every decode step), the API
documented in docs/serving.md.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sparse
from repro.configs.base import get_config
from repro.launch.serve import build_stream_matrix
from repro.models import model as M

B, PROMPT, GEN = 4, 16, 12

for arch in ("gemma3-12b", "recurrentgemma-9b", "falcon-mamba-7b"):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        2, cfg.vocab_size - 1, size=(B, PROMPT)).astype(np.int32)
    cache = M.init_cache(cfg, B, PROMPT + GEN)
    step = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    t0 = time.perf_counter()
    tok = jnp.asarray(prompts[:, 0])
    for t in range(PROMPT - 1):
        _, cache = step(params, cache, jnp.asarray(prompts[:, t]),
                        jnp.int32(t))
    tok = jnp.asarray(prompts[:, -1])
    gen = []
    for t in range(GEN):
        logits, cache = step(params, cache, tok, jnp.int32(PROMPT - 1 + t))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
        gen.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    print(f"{arch:20s} [{cfg.family:6s}] generated {GEN}x{B} tokens "
          f"in {dt:5.1f}s -> {np.stack(gen, 1)[0][:6]}")

# Block-sparse serving path: the MoE expert-dispatch matrix (dense expert
# blocks on the diagonal — repro.models.moe's bucketed-token structure)
# held for the whole serving session.  sparse.plan classifies, predicts,
# and converts ONCE with the decode length as the reuse horizon; each
# decode step then replays the bound kernel on that step's activations.
N_SLOTS, D_MODEL = 1024, 64
m = build_stream_matrix("moe-block", N_SLOTS)
plan = sparse.plan(m, sparse.BSpec(d=D_MODEL, reuse=GEN))
rng = np.random.default_rng(0)
acts = jnp.asarray(rng.normal(size=(GEN, N_SLOTS, D_MODEL))
                   .astype(np.float32))
t0 = time.perf_counter()
outs = jax.block_until_ready(plan.execute_many(acts))
dt = time.perf_counter() - t0
print(f"{'moe-block-spmm':20s} [stream] served {GEN} steps of "
      f"[{N_SLOTS},{D_MODEL}] in {dt:5.1f}s via {plan.chosen} "
      f"({plan.dispatch.regime} regime, "
      f"executed={plan.stats()['executed']}/"
      f"{plan.stats()['planned_reuse']} planned)")
