"""Batched serving example: prefill + greedy decode with KV/recurrent
caches on three different architecture families (attention, hybrid, SSM).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M

B, PROMPT, GEN = 4, 16, 12

for arch in ("gemma3-12b", "recurrentgemma-9b", "falcon-mamba-7b"):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        2, cfg.vocab_size - 1, size=(B, PROMPT)).astype(np.int32)
    cache = M.init_cache(cfg, B, PROMPT + GEN)
    step = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    t0 = time.perf_counter()
    tok = jnp.asarray(prompts[:, 0])
    for t in range(PROMPT - 1):
        _, cache = step(params, cache, jnp.asarray(prompts[:, t]),
                        jnp.int32(t))
    tok = jnp.asarray(prompts[:, -1])
    gen = []
    for t in range(GEN):
        logits, cache = step(params, cache, tok, jnp.int32(PROMPT - 1 + t))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
        gen.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    print(f"{arch:20s} [{cfg.family:6s}] generated {GEN}x{B} tokens "
          f"in {dt:5.1f}s -> {np.stack(gen, 1)[0][:6]}")
