"""End-to-end driver: train a small LM for a few hundred steps with the
full production substrate (pipeline -> pjit-able step -> checkpointing ->
straggler watchdog), then resume from the checkpoint to prove restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200

Default config is a ~2M-param llama-style model so 200 steps finish in
minutes on one CPU core; pass --arch/--steps to scale up (the same driver
trains the assigned full configs on a real slice).
"""
import argparse
import dataclasses
import tempfile

from repro.configs.base import ShapeConfig, get_config
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        num_layers=4, d_model=128, num_heads=4, head_dim=32, d_ff=512,
        vocab_size=2048)
    shape = ShapeConfig("example", seq_len=128, global_batch=8,
                        kind="train")
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainerConfig(
        ckpt_dir=ckpt_dir, ckpt_every=50,
        schedule_kwargs={"warmup_steps": 20, "total_steps": args.steps})
    trainer = Trainer(cfg, shape, tcfg,
                      opt_cfg=adamw.AdamWConfig(lr=1e-3),
                      data_cfg=DataConfig(seed=0))
    trainer.init_or_restore()
    print(f"params={cfg.param_count() / 1e6:.2f}M  tokens/step="
          f"{shape.seq_len * shape.global_batch}")
    trainer.run(args.steps, stop_after=args.steps // 2)
    mid_losses = [h["loss"] for h in trainer.history]
    print(f"pre-restart: step {trainer.history[-1]['step']} "
          f"loss {mid_losses[-1]:.3f}")

    # Simulated preemption: a NEW trainer resumes from the checkpoint.
    resumed = Trainer(cfg, shape, tcfg,
                      opt_cfg=adamw.AdamWConfig(lr=1e-3),
                      data_cfg=DataConfig(seed=0))
    resumed.init_or_restore()
    print(f"resumed at step {resumed.start_step}")
    resumed.run(args.steps)
    losses = mid_losses + [h["loss"] for h in resumed.history]
    k = max(len(losses) // 10, 1)
    print(f"loss: first10%={sum(losses[:k]) / k:.3f} "
          f"last10%={sum(losses[-k:]) / k:.3f}")
    assert sum(losses[-k:]) / k < sum(losses[:k]) / k, "loss did not drop"
    print("OK: trained, checkpointed, restarted, loss decreased")


if __name__ == "__main__":
    main()
