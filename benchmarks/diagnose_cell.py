"""Perf-iteration diagnostic: top FLOP/byte/collective contributors
for one (arch x shape) cell.

    PYTHONPATH=src python benchmarks/diagnose_cell.py <arch> <shape> [ga]
    REPRO_CAUSAL_IMPL=triangle ... to flip the causal implementation.
"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch import dryrun as DR
from repro.configs.base import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.train import train_step as TS
from repro.optim import adamw
from repro.core import hlo_flops as HF

arch, shape_name = sys.argv[1], sys.argv[2]
ga = int(sys.argv[3]) if len(sys.argv) > 3 else 0
if ga <= 0:
    ga = DR.GRAD_ACCUM_DEFAULTS.get((arch, shape_name), 1)
from repro.models import attention as ATT
ATT.set_causal_impl(os.environ.get("REPRO_CAUSAL_IMPL", "masked"))
cfg = get_config(arch)
shape = SHAPES[shape_name]
mesh = make_production_mesh()
with mesh:
    params_abs, cache_abs = DR.abstract_state(cfg, shape, shape.kind)
    specs = DR.input_specs(cfg, shape)
    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(state_dtype=DR.OPT_DTYPE_DEFAULTS.get(arch, "float32"))
        step, _ = TS.make_train_step(cfg, shape, mesh, opt_cfg=opt_cfg, grad_accum=ga)
        opt_abs = jax.eval_shape(lambda p: adamw.init_state(p, opt_cfg), params_abs)
        lowered = step.lower(params_abs, opt_abs, specs, jax.ShapeDtypeStruct((), jax.numpy.int32))
    elif shape.kind == "prefill":
        step, _ = TS.make_prefill_step(cfg, shape, mesh)
        lowered = step.lower(params_abs, specs)
    else:
        step, _ = TS.make_serve_step(cfg, shape, mesh)
        lowered = step.lower(params_abs, cache_abs, specs["tokens"], specs["pos"])
    comp = lowered.compile()
txt = comp.as_text()
s = HF.analyze_hlo(txt)
print(f"flops/dev {s['flops']:.4g}  bytes/dev {s['bytes_accessed']:.4g}")
print("collectives:", {k: f"{v:.3g}" for k, v in s["collective_bytes"].items()})
for kind in ("collective", "bytes", "flops"):
    print(f"== top {kind} ==")
    for v, desc in HF.top_contributors(txt, kind, k=8):
        print(f"  {v:10.3e}  {desc}")
