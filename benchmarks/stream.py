"""STREAM-style bandwidth measurement (paper Section IV-B).

The paper measures beta = 122.6 GB/s on the Perlmutter socket with STREAM;
we measure the same quantity on this host so the roofline ceilings are
grounded in measured bandwidth, not guesses.  Triad (a = b + s*c) is the
canonical figure; copy is reported for reference.
"""
from __future__ import annotations

import time

import numpy as np


def measure_bandwidth(n_bytes: int = 256 * 2 ** 20, repeats: int = 5):
    """Returns dict with copy/triad bandwidths in bytes/s."""
    n = n_bytes // 8
    a = np.zeros(n)
    b = np.random.default_rng(0).random(n)
    c = np.random.default_rng(1).random(n)

    def timed(fn, traffic):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return traffic / best

    copy_bw = timed(lambda: np.copyto(a, b), 2 * n * 8)

    def triad():
        np.multiply(c, 3.0, out=a)
        np.add(a, b, out=a)

    triad_bw = timed(triad, 3 * n * 8)
    return {"copy": copy_bw, "triad": triad_bw}


if __name__ == "__main__":
    bw = measure_bandwidth()
    print(f"copy  {bw['copy'] / 1e9:.2f} GB/s")
    print(f"triad {bw['triad'] / 1e9:.2f} GB/s")
