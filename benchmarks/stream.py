"""STREAM bandwidth measurement + the streamed-dispatch serving suite.

Two related benchmarks share this module:

1. ``measure_bandwidth`` — STREAM-style copy/triad (paper Section IV-B).
   The paper measures beta = 122.6 GB/s on the Perlmutter socket; we
   measure the same quantity on this host so the roofline ceilings are
   grounded in measured bandwidth, not guesses.

2. ``run_stream_suite`` — streamed vs per-call dispatch across the four
   paper sparsity structures (block, banded, scale-free, uniform) and
   varying B widths, through the public ``sparse.plan`` / ``sparse.spmm``
   API (never raw kernels).  Three modes per (matrix, d, reuse) cell:

     stream          ``sparse.plan(m, BSpec(d, reuse)).execute(b)`` — one
                     classification + conversion, then zero-dispatch replay.
     percall         a fresh Dispatcher per call: classification, policy,
                     roofline evaluation, and conversion paid on every
                     right-hand side (dispatch with no persistent state).
     percall_cached  one Dispatcher, ``spmm`` per call: plan/conversion
                     caches warm after the first call, but every call still
                     pays validation + cache lookups.

   Totals include planning and conversion, so the cells answer the serving
   question directly: at what reuse does planning once pay for itself?
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Dict, List, Tuple

import numpy as np


def _best_of(fn, repeats: int) -> float:
    """Min-of-N wall-clock of ``fn()`` (the suite's timing primitive)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def measure_bandwidth(n_bytes: int = 256 * 2 ** 20, repeats: int = 5):
    """Returns dict with copy/triad bandwidths in bytes/s."""
    n = n_bytes // 8
    a = np.zeros(n)
    b = np.random.default_rng(0).random(n)
    c = np.random.default_rng(1).random(n)

    copy_bw = 2 * n * 8 / _best_of(lambda: np.copyto(a, b), repeats)

    def _triad():
        np.multiply(c, 3.0, out=a)
        np.add(a, b, out=a)

    triad_bw = 3 * n * 8 / _best_of(_triad, repeats)
    return {"copy": copy_bw, "triad": triad_bw}


# --------------------------------------------------------------------------
# Streamed vs per-call dispatch suite (docs/serving.md).
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StreamCell:
    """One (matrix x d x reuse x mode) measurement of the serving suite."""

    matrix: str
    pattern: str
    mode: str                 # "stream" | "percall" | "percall_cached"
    d: int
    reuse: int
    nnz: int
    total_s: float            # wall time for the whole stream, incl. planning
    gflops: float             # useful FLOPs / total_s
    ai_model: float           # chosen candidate's sparsity-aware AI
    predicted_gflops: float   # amortized prediction at this reuse horizon
    chosen: str               # format this mode actually executed
    dtype: str = "f32i32"     # storage-precision token the mode ran at


def stream_matrices(scale: int) -> Dict[str, object]:
    """The four paper structures at n = 2**scale (generator thunks).

    Delegates to ``repro.core.patterns.serving_suite`` — the same
    registry ``repro.launch.serve --spmm-stream`` serves — so the demo
    and this CI-gated suite measure identical operators.
    """
    from repro.core.patterns import serving_suite
    return {f"{name}_{scale}": gen
            for name, gen in serving_suite(2 ** scale).items()}


def _rhs_stream(n: int, d: int, k: int, seed: int = 0) -> List:
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
            for _ in range(k)]


def run_stream_suite(beta: float, *, scale: int = 11,
                     d_values: Tuple[int, ...] = (16, 64),
                     reuses: Tuple[int, ...] = (1, 8, 32),
                     repeats: int = 2) -> List[StreamCell]:
    """Measure streamed vs per-call dispatch across structures x d x reuse.

    Every mode goes through the public API (``sparse.plan`` or
    ``sparse.spmm``); every total includes that mode's planning and
    conversion work, which is exactly what distinguishes the modes.
    """
    import jax
    from repro import sparse
    from benchmarks.spmm_suite import make_dispatcher

    results: List[StreamCell] = []
    for name, gen in stream_matrices(scale).items():
        m = gen()
        for d in d_values:
            for r in reuses:
                # Deterministic per-(matrix, d) seed: the claim check gates
                # CI, so its inputs must reproduce across runs.
                seed = zlib.adler32(f"{name}:{d}".encode()) % 2 ** 16
                bs = _rhs_stream(m.n, d, r, seed=seed)

                def run_stream():
                    disp = make_dispatcher(beta)
                    p = sparse.plan(m, sparse.BSpec(d=d, reuse=r),
                                    dispatcher=disp)
                    out = None
                    for b in bs:
                        out = p.execute(b)
                    jax.block_until_ready(out)
                    return p

                def run_percall():
                    out = None
                    for b in bs:
                        disp = make_dispatcher(beta)
                        out = disp.spmm(m, b, reuse=1)
                    jax.block_until_ready(out)

                def run_cached():
                    disp = make_dispatcher(beta)
                    out = None
                    for b in bs:
                        out = disp.spmm(m, b)
                    jax.block_until_ready(out)
                    return disp

                # Audit plans for the three modes: percall plans at
                # reuse=1; percall_cached executes the dispatcher-default
                # horizon — label each row with the plan that mode actually
                # runs (they can differ when amortization flips the
                # choice).  One execute per distinct format warms the jit
                # cache (shapes are uniform across the stream), so compile
                # time doesn't contaminate whichever mode is timed first.
                audit_disp = make_dispatcher(beta)
                plan_obj = sparse.plan(m, sparse.BSpec(d=d, reuse=r),
                                       dispatcher=audit_disp)
                single = audit_disp.plan(m, d, reuse=1)
                cached_plan = audit_disp.plan(m, d)
                jax.block_until_ready(plan_obj.execute(bs[0]))
                for fmt in {single.chosen, cached_plan.chosen} - \
                        {plan_obj.chosen}:
                    jax.block_until_ready(
                        audit_disp.spmm(m, bs[0], strategy=fmt))

                flops = 2.0 * m.nnz * d * r
                audit = plan_obj.dispatch.candidate(plan_obj.chosen)
                single_audit = single.candidate(single.chosen)
                cached_audit = cached_plan.candidate(cached_plan.chosen)
                for mode, fn, chosen, aud, tok in (
                        ("stream", run_stream, plan_obj.chosen, audit,
                         plan_obj.precision),
                        ("percall", run_percall, single.chosen, single_audit,
                         single.precision),
                        ("percall_cached", run_cached, cached_plan.chosen,
                         cached_audit, cached_plan.precision)):
                    total = _best_of(fn, repeats)
                    results.append(StreamCell(
                        matrix=name, pattern=m.pattern, mode=mode, d=d,
                        reuse=r, nnz=m.nnz, total_s=total,
                        gflops=flops / total / 1e9,
                        ai_model=aud.ai or 0.0,
                        predicted_gflops=aud.amortized_gflops or 0.0,
                        chosen=chosen, dtype=tok))
    return results


def stream_claims_check(cells: List[StreamCell]) -> Dict[str, bool]:
    """Serving-path acceptance: plan-once must win once reuse amortizes.

    The claim gates CI (``benchmarks/run.py --smoke``): for every
    *structure*, summed over its d cells at reuse >= 8, the streamed
    total wall time must beat per-call dispatch — otherwise the whole
    streaming layer is overhead.  Aggregating per matrix (rather than
    per cell) keeps the gate meaningful while tolerating this host's
    single-cell wall-clock spikes (2x swings between identical runs;
    see the verify notes and spmm_suite's nnz filter for the same
    issue in the single-shot claims).
    """
    totals: Dict[str, Dict[str, float]] = {}
    for c in cells:
        if c.reuse < 8:
            continue
        totals.setdefault(c.matrix, {}).setdefault(c.mode, 0.0)
        totals[c.matrix][c.mode] += c.total_s
    verdicts = [by_mode["stream"] < by_mode["percall"]
                for by_mode in totals.values()
                if "stream" in by_mode and "percall" in by_mode]
    return {
        "stream_plan_once_beats_percall_at_reuse_8plus":
            bool(verdicts) and all(verdicts),
    }


def to_csv_rows(cells: List[StreamCell]) -> List[str]:
    """Render cells in the smoke_spmm.csv schema (no header).

    Columns mirror benchmarks/spmm_suite.to_csv so the streamed rows
    append onto the same uploaded artifact: the mode and reuse horizon are
    encoded in the impl column (``stream_r8``, ``percall_r8``, ...).
    """
    rows = []
    for c in cells:
        frac = c.gflops / c.predicted_gflops if c.predicted_gflops else 0.0
        rows.append(f"{c.matrix},{c.pattern},{c.mode}_r{c.reuse},{c.d},"
                    f"{c.nnz},{c.gflops:.4f},{c.ai_model:.5f},"
                    f"{c.predicted_gflops:.4f},{frac:.4f},{c.chosen},"
                    f"{c.dtype}")
    return rows


# --------------------------------------------------------------------------
# Sharded-execution lane (docs/sharding.md).
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ShardCell:
    """One (matrix x d x tier) measurement of the sharded lane."""

    matrix: str
    pattern: str
    impl: str                 # "single" | "shard{D}_{b_strategy}"
    d: int
    nnz: int
    devices: int
    steady_s: float           # best-of per-execute wall time (post warm-up)
    gflops: float             # useful FLOPs / steady_s
    ai_model: float           # critical-shard AI (single tier: candidate AI)
    predicted_gflops: float   # cost-model prediction for this tier
    chosen: str               # format the plan executes
    speedup: float            # gflops / the single-device cell's gflops
    dtype: str = "f32i32"     # storage-precision token the tier ran at


def run_shard_suite(beta: float, *, scale: int = 10,
                    d_values: Tuple[int, ...] = (64,),
                    repeats: int = 3) -> List[ShardCell]:
    """Sharded vs single-device steady-state replay across structures x d.

    Plans each structure twice through the public API — once as a plain
    ``sparse.plan`` and once with ``mesh=make_shard_mesh()`` over every
    visible device — and times the steady-state ``execute`` (planning,
    packing, and the first compile are warmed up outside the timer; the
    lane measures replay throughput, which is what the sharded tier
    exists to scale).  On CPU export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` first.
    """
    import jax
    import jax.numpy as jnp
    from repro import sparse
    from repro.launch.mesh import make_shard_mesh
    from benchmarks.spmm_suite import make_dispatcher

    mesh = make_shard_mesh()
    devices = len(jax.devices())
    results: List[ShardCell] = []
    for name, gen in stream_matrices(scale).items():
        m = gen()
        for d in d_values:
            seed = zlib.adler32(f"shard:{name}:{d}".encode()) % 2 ** 16
            b = _rhs_stream(m.n, d, 1, seed=seed)[0]
            flops = 2.0 * m.nnz * d
            disp = make_dispatcher(beta)
            single = sparse.plan(m, sparse.BSpec(d=d), dispatcher=disp)
            sharded = sparse.plan(m, sparse.BSpec(d=d), mesh=mesh,
                                  dispatcher=disp)
            tiers = [("single", single), (
                f"shard{sharded.num_shards}_{sharded.b_strategy}", sharded)]
            base = None
            for impl, p in tiers:
                jax.block_until_ready(p.execute(b))        # warm-up/compile
                t = _best_of(
                    lambda: jax.block_until_ready(p.execute(b)), repeats)
                gf = flops / t / 1e9
                if impl == "single":
                    base = gf
                    aud = p.dispatch.candidate(p.chosen)
                    ai, pred = aud.ai or 0.0, aud.predicted_gflops or 0.0
                else:
                    ev = next(e for e in p.strategy_evals
                              if e.strategy == p.b_strategy)
                    ai = ev.roofline.shard_ai
                    pred = ev.predicted_gflops or 0.0
                results.append(ShardCell(
                    matrix=name, pattern=m.pattern, impl=impl, d=d,
                    nnz=m.nnz, devices=p.num_shards
                    if impl != "single" else 1,
                    steady_s=t, gflops=gf, ai_model=ai,
                    predicted_gflops=pred, chosen=p.chosen,
                    speedup=gf / base if base else 0.0,
                    dtype=p.precision))
    return results


def shard_claims_check(cells: List[ShardCell]) -> Dict[str, bool]:
    """Sharded-lane acceptance: the mesh must pay off somewhere.

    The target is >= 1.5x single-device GFLOP/s on at least one
    (structure, d) cell.  On a single-core host the 8 "devices" are
    virtual and share one core, so this claim is reported (the CSV rows
    carry every speedup either way) but only meaningful on runners with
    real parallelism — the smoke job soft-reports it rather than
    hard-failing (same policy as the wall-clock-spiky stream claims).
    """
    speedups = [c.speedup for c in cells if c.impl != "single"]
    return {
        "shard_1_5x_single_device_somewhere":
            bool(speedups) and max(speedups) >= 1.5,
    }


def shard_csv_rows(cells: List[ShardCell]) -> List[str]:
    """Render sharded cells in the smoke_spmm.csv schema (no header).

    The tier and chosen B-strategy are encoded in the impl column
    (``single`` / ``shard8_all_gather``); the roofline-fraction column
    carries the measured speedup over the single tier instead (0 for the
    single rows themselves, which ARE the baseline).
    """
    rows = []
    for c in cells:
        rows.append(f"{c.matrix},{c.pattern},{c.impl},{c.d},"
                    f"{c.nnz},{c.gflops:.4f},{c.ai_model:.5f},"
                    f"{c.predicted_gflops:.4f},{c.speedup:.4f},{c.chosen},"
                    f"{c.dtype}")
    return rows


# --------------------------------------------------------------------------
# Continuous-batching engine lane (docs/serving_engine.md).
# --------------------------------------------------------------------------

@dataclasses.dataclass
class EngineCell:
    """One (matrix x impl) measurement of the engine-vs-sync lane.

    Unlike the throughput-shaped cells above, this lane reports serving
    SLOs: per-request submit-to-completion latency percentiles plus
    goodput (served requests per second of serving span).
    """

    matrix: str
    pattern: str
    impl: str                 # "engine" | "sync"
    d: int                    # per-request RHS width
    nnz: int
    streams: int              # concurrent logical request streams
    requests: int             # total requests served
    batches: int              # launches: engine micro-batches / sync calls
    p50_us: float             # median per-request latency
    p99_us: float
    goodput_rps: float        # requests per second over the serving span
    dtype: str = "f32i32"     # storage-precision token the plan served at


#: Header for the engine lane's own CSV (latency columns don't fit the
#: GFLOP/s-shaped ``spmm_suite.CSV_HEADER``; ``tools/perf_trend.py``
#: trends this file with ``--metric goodput_rps``).
ENGINE_CSV_HEADER = ("matrix,pattern,impl,d,nnz,streams,requests,"
                     "batches,p50_us,p99_us,goodput_rps,dtype")


def run_engine_suite(beta: float, *, scale: int = 10, d: int = 8,
                     streams: int = 4, per_stream: int = 8,
                     repeats: int = 3) -> List[EngineCell]:
    """Engine-vs-sync serving comparison across the four structures.

    The serving scenario the engine exists for: ``streams`` concurrent
    request streams of *narrow* right-hand sides (``d`` columns each —
    the per-request width of real serving traffic) with a reuse horizon
    of ``per_stream`` requests per stream.  The engine side admits every
    request up front (round-robin across streams, the queue depth a
    bursty open-loop arrival process produces) and drains through
    coalesced ``execute_wide`` micro-batches; the sync side replays the
    identical request sequence one ``execute_wide`` + sync at a time —
    exactly what ``serve.py --spmm-stream`` does per request today.

    Both sides are warmed (launch-width size classes for the engine, the
    per-request shape for sync) so jit compiles stay out of the
    latencies, and both run ``repeats`` passes keeping the best-goodput
    pass — the same best-of discipline as ``_best_of`` above.

    Determinism note: the engine pass drives :meth:`ServingEngine.drain`
    on the caller's thread (no worker thread, no arrival jitter), so the
    coalescing decisions — and therefore CI's claim verdict — reproduce
    across runs.
    """
    import jax
    import jax.numpy as jnp
    from repro import sparse
    from benchmarks.spmm_suite import make_dispatcher

    total = streams * per_stream
    results: List[EngineCell] = []
    for name, gen in stream_matrices(scale).items():
        m = gen()
        seed = zlib.adler32(f"engine:{name}:{d}".encode()) % 2 ** 16
        rng = np.random.default_rng(seed)
        # Round-robin interleave so consecutive queue entries come from
        # different streams, like concurrent arrivals would.
        reqs = [jnp.asarray(rng.normal(size=(m.n, d)).astype(np.float32))
                for _ in range(total)]
        disp = make_dispatcher(beta)
        plan = sparse.plan(
            m, sparse.BSpec(d=d, reuse=total * repeats), dispatcher=disp)

        engine = sparse.ServingEngine(
            max_queue=2 * total, policy="wait", auto_replan=False)
        engine.register("spmm", plan)
        engine.warmup("spmm", max_cols=total * d)
        jax.block_until_ready(plan.execute_wide(reqs[0]))   # sync shape
        plan.reset_stats()

        best_engine = None
        for _ in range(repeats):
            engine.reset_stats()
            tickets = [engine.submit("spmm", b) for b in reqs]
            engine.drain()
            stats = engine.stats()
            assert all(t.done() for t in tickets)
            if (best_engine is None
                    or stats["goodput_rps"] > best_engine["goodput_rps"]):
                best_engine = stats
        results.append(EngineCell(
            matrix=name, pattern=m.pattern, impl="engine", d=d, nnz=m.nnz,
            streams=streams, requests=total,
            batches=best_engine["batches"],
            p50_us=best_engine["p50_us"], p99_us=best_engine["p99_us"],
            goodput_rps=best_engine["goodput_rps"], dtype=plan.precision))

        best_sync = None
        for _ in range(repeats):
            lats = []
            t0 = time.perf_counter()
            for b in reqs:
                t1 = time.perf_counter()
                jax.block_until_ready(plan.execute_wide(b))
                lats.append(time.perf_counter() - t1)
            span = time.perf_counter() - t0
            goodput = total / max(span, 1e-12)
            if best_sync is None or goodput > best_sync[0]:
                best_sync = (goodput, lats)
        sync_us = np.asarray(best_sync[1]) * 1e6
        results.append(EngineCell(
            matrix=name, pattern=m.pattern, impl="sync", d=d, nnz=m.nnz,
            streams=streams, requests=total, batches=total,
            p50_us=float(np.percentile(sync_us, 50)),
            p99_us=float(np.percentile(sync_us, 99)),
            goodput_rps=best_sync[0], dtype=plan.precision))
    return results


def engine_claims_check(cells: List[EngineCell]) -> Dict[str, bool]:
    """Engine-lane acceptance: coalescing must beat per-request sync.

    The CI-gated claim (ISSUE 7 acceptance): at reuse >= 8 per stream
    with >= 4 concurrent streams, coalesced ``execute_wide`` serving
    beats per-request sync replay on goodput.  Aggregated over the four
    structures — total requests over total serving span — the same
    noise-tolerance argument as ``stream_claims_check``: single
    structures swing with wall-clock spikes on shared hosts (banded's
    small-nnz cells are within noise of sync), while the aggregate is
    dominated by the structures coalescing actually helps.
    """
    spans = {"engine": 0.0, "sync": 0.0}
    reqs = {"engine": 0, "sync": 0}
    for c in cells:
        if c.impl in spans and c.goodput_rps > 0:
            spans[c.impl] += c.requests / c.goodput_rps
            reqs[c.impl] += c.requests
    ok = (reqs["engine"] > 0 and reqs["sync"] > 0
          and spans["engine"] > 0
          and reqs["engine"] / spans["engine"]
          > reqs["sync"] / spans["sync"])
    return {"engine_coalescing_beats_sync_goodput_at_reuse8_4streams": ok}


def engine_csv_rows(cells: List[EngineCell]) -> List[str]:
    """Render engine cells under :data:`ENGINE_CSV_HEADER` (no header)."""
    return [f"{c.matrix},{c.pattern},{c.impl},{c.d},{c.nnz},{c.streams},"
            f"{c.requests},{c.batches},{c.p50_us:.1f},{c.p99_us:.1f},"
            f"{c.goodput_rps:.2f},{c.dtype}"
            for c in cells]


if __name__ == "__main__":
    import pathlib
    import sys
    # Script invocation (python benchmarks/stream.py) puts benchmarks/ on
    # sys.path, not the repo root; the suite imports benchmarks.spmm_suite.
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    bw = measure_bandwidth()
    print(f"copy  {bw['copy'] / 1e9:.2f} GB/s")
    print(f"triad {bw['triad'] / 1e9:.2f} GB/s")
    for cell in run_stream_suite(bw["triad"], scale=10, repeats=1):
        print(f"{cell.matrix:14s} {cell.mode:14s} d={cell.d:3d} "
              f"r={cell.reuse:3d} {cell.total_s * 1e3:8.2f} ms "
              f"{cell.gflops:7.2f} GF/s  chosen={cell.chosen}")
    for sc in run_shard_suite(bw["triad"], scale=10, repeats=1):
        print(f"{sc.matrix:14s} {sc.impl:20s} d={sc.d:3d} "
              f"{sc.steady_s * 1e6:9.1f} us {sc.gflops:7.2f} GF/s "
              f"x{sc.speedup:.2f}")
    for ec in run_engine_suite(bw["triad"], scale=10, repeats=1):
        print(f"{ec.matrix:14s} {ec.impl:7s} d={ec.d:3d} "
              f"x{ec.requests} in {ec.batches:3d} launches  "
              f"p50={ec.p50_us:8.0f}us p99={ec.p99_us:8.0f}us  "
              f"{ec.goodput_rps:8.1f} req/s")
