"""Render the EXPERIMENTS.md roofline tables from dry-run records.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        [--dir experiments/dryrun] [--out experiments/roofline_table.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.core.analyzer import analyze_record


def load_records(d: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(analyze_record(json.load(f)))
    return recs


def table(recs, mesh_filter=None) -> str:
    rows = [
        "| arch | shape | mesh | ga | compute_s | memory_s | collective_s "
        "| dominant | MODEL/HLO | MFU ceiling | HBM GiB/chip |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if mesh_filter and rec["mesh"] != mesh_filter:
            continue
        r = rec["roofline"]
        mem = rec["memory"]
        gb = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
              + mem["output_size_in_bytes"]
              - mem.get("alias_size_in_bytes", 0)) / 2 ** 30
        rows.append(
            "| {a} | {s} | {m} | {ga} | {c:.3e} | {mm:.3e} | {k:.3e} | "
            "{dom} | {ratio:.2f} | {mfu:.2%} | {gb:.1f} |".format(
                a=rec["arch"], s=rec["shape"], m=rec["mesh"],
                ga=rec.get("grad_accum", 1), c=r["compute_s"],
                mm=r["memory_s"], k=r["collective_s"], dom=r["dominant"],
                ratio=r["useful_compute_ratio"],
                mfu=r["mfu_upper_bound"], gb=gb))
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline_table.md")
    args = ap.parse_args()
    recs = load_records(args.dir)
    md = ["## Single-pod (16x16 = 256 chips)", "",
          table(recs, "16x16"), "",
          "## Multi-pod (2x16x16 = 512 chips)", "",
          table(recs, "2x16x16"), ""]
    out = "\n".join(md)
    with open(args.out, "w") as f:
        f.write(out)
    print(f"wrote {args.out} ({len(recs)} records)")


if __name__ == "__main__":
    main()
