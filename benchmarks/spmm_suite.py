"""Paper Tables III/V + Figures 1/2: SpMM throughput vs sparsity-aware
roofline predictions.

For every (matrix x implementation x d) cell we measure wall-clock GFLOP/s
of the jitted SpMM (the paper's Table V), classify the matrix, evaluate the
matching sparsity-aware AI model, and compare attained performance against
the measured-bandwidth roofline P = beta * AI (the paper's Figure 2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import numpy as np

from repro import sparse
from repro.configs.paper_spmm import CONFIG as SPMM_CONFIG
from repro.core import classify
from repro.core.hardware import HardwareSpec
from repro.core.patterns import paper_suite


def _time_call(fn, *args, repeats: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


@dataclasses.dataclass
class CellResult:
    matrix: str
    pattern: str
    impl: str
    d: int
    nnz: int
    gflops: float
    ai_model: float
    predicted_gflops: float      # beta * AI (bandwidth roof)
    roofline_fraction: float


def run_suite(beta: float, scale: int | None = None,
              d_values=None, impls=None, repeats=None) -> List[CellResult]:
    cfg = SPMM_CONFIG
    scale = scale or cfg.scale
    d_values = d_values or cfg.d_values
    impls = impls or cfg.implementations
    repeats = repeats or cfg.repeats
    results: List[CellResult] = []
    rng = np.random.default_rng(0)

    for name, gen in paper_suite(scale).items():
        m = gen()
        report = classify(m)
        # Implementation applicability (emitted as skips, not silence):
        #  - ELL padding explodes on hub matrices (max_deg >> avg_deg);
        #    vendor kernels fall back to CSR there too.
        #  - dense-block BCSR (the TPU layout) inflates stored FLOPs by
        #    t^2/D; past ~64x the CPU proxy measurement is meaningless —
        #    exactly what ai_blocked_tpu predicts (mxu_utilization -> 0).
        deg = np.bincount(m.rows, minlength=m.n)
        ell_ok = deg.max() <= max(64, 16 * max(deg.mean(), 1))
        t = cfg.bcsr_block
        bstats = classify(m, probe_t=t).stats
        bcsr_inflation = (t * t) / max(bstats[f"block_D"], 1e-9)
        bcsr_ok = bcsr_inflation <= 64
        formats = {}
        if "csr" in impls:
            formats["csr"] = (sparse.csr_spmm, sparse.coo_to_csr(m))
        if "ell" in impls and ell_ok:
            formats["ell"] = (sparse.ell_spmm, sparse.coo_to_ell(m))
        if "bcsr" in impls and bcsr_ok:
            formats["bcsr"] = (sparse.bcsr_spmm, sparse.coo_to_bcsr(m, t))
        if not ell_ok:
            print(f"# skip ell on {name}: max_deg {deg.max()} >> avg "
                  f"{deg.mean():.1f}")
        if not bcsr_ok:
            print(f"# skip bcsr on {name}: dense-block inflation "
                  f"{bcsr_inflation:.0f}x (ai_blocked_tpu predicts "
                  f"mxu_util {1/bcsr_inflation:.3f})")
        for d in d_values:
            b = np.asarray(rng.normal(size=(m.n, d)), dtype=np.float32)
            b = jax.numpy.asarray(b)
            # Model prediction for this matrix's detected regime, with
            # fp32 values (this host) — the paper uses fp64 on Perlmutter.
            tb = report.traffic(d, sizeof_val=4)
            pred = beta * tb.ai
            for impl, (fn, mat) in formats.items():
                dt = _time_call(fn, mat, b, repeats=repeats)
                gflops = 2.0 * m.nnz * d / dt / 1e9
                results.append(CellResult(
                    matrix=name, pattern=m.pattern, impl=impl, d=d,
                    nnz=m.nnz, gflops=gflops, ai_model=tb.ai,
                    predicted_gflops=pred / 1e9,
                    roofline_fraction=gflops / (pred / 1e9)))
    return results


def paper_claims_check(results: List[CellResult]) -> Dict[str, bool]:
    """The paper's qualitative claims, validated on our measurements.

    1. random sparsity is the slowest regime (Section IV-C)
    2. performance improves with d (lowest at d=1) (Section IV-C)
    3. structured (diagonal/blocked at large d) beats random (Fig. 1)
    4. blocked-regime BCSR approaches its roofline better than random-CSR
       approaches the random roofline upper bound region (Section IV-D)
    """
    # Degree-~1 matrices (er_*_1, ideal_diagonal) have nnz ~ n: their B
    # gather fits in cache and the sub-ms kernel measures dispatch
    # overhead, not bandwidth — exclude them from *regime* aggregates
    # (they stay in the full table).  Threshold: nnz >= 4n.
    n_rows = {r.matrix: r.nnz for r in results}
    big = {m for m, nnz in n_rows.items()
           if nnz >= 4 * min(n_rows.values())}

    def mean_gf(pattern=None, impl=None, d=None, prefix=None):
        xs = [r.gflops for r in results
              if (pattern is None or r.pattern == pattern)
              and (impl is None or r.impl == impl)
              and (d is None or r.d == d)
              and (prefix is None or r.matrix.startswith(prefix))
              and r.matrix in big]
        return float(np.mean(xs)) if xs else float("nan")

    d_vals = sorted({r.d for r in results})
    by_d = [np.mean([r.gflops for r in results if r.d == d])
            for d in d_vals]
    # Regime comparisons use the CSR implementation (the common baseline,
    # like the paper's Fig. 1 trends); the dense-block claim uses the
    # FEM-style matrices where CSB/BCSR's layout is applicable.
    mid_d = d_vals[len(d_vals) // 2]
    claims = {
        # Structured (banded/blocked) locality beats random — strongest at
        # the paper's mid-range d where B reuse matters and the working
        # set still partially caches (paper Fig. 1 trends).
        "random_below_structured": (
            mean_gf("random", impl="csr", d=mid_d) <
            min(mean_gf("diagonal", impl="csr", d=mid_d),
                mean_gf("blocked", impl="csr", d=mid_d))),
        "perf_grows_with_d": by_d[0] == min(by_d),
        "structured_beats_random_at_large_d": (
            mean_gf("blocked", impl="csr", d=d_vals[-1]) >
            mean_gf("random", impl="csr", d=d_vals[-1]) * 0.9),
        "bcsr_best_on_dense_blocks": (
            mean_gf(impl="bcsr", prefix="fem") >=
            mean_gf(impl="csr", prefix="fem") * 0.8),
        # Paper: scale-free is the FASTEST regime (hub rows cache).  On
        # this 1-core XLA host the gather pipeline is instruction-bound,
        # not DRAM-bound, so we only assert parity with random; the
        # refuted stronger form is discussed in EXPERIMENTS.md.
        "scale_free_not_below_random": (
            mean_gf("scale_free", impl="csr") >=
            mean_gf("random", impl="csr") * 0.9),
    }
    return claims


def to_csv(results: List[CellResult]) -> str:
    lines = ["matrix,pattern,impl,d,nnz,gflops,ai_model,"
             "predicted_gflops,roofline_fraction"]
    for r in results:
        lines.append(f"{r.matrix},{r.pattern},{r.impl},{r.d},{r.nnz},"
                     f"{r.gflops:.4f},{r.ai_model:.5f},"
                     f"{r.predicted_gflops:.4f},"
                     f"{r.roofline_fraction:.4f}")
    return "\n".join(lines)
