"""Paper Tables III/V + Figures 1/2: SpMM throughput vs sparsity-aware
roofline predictions, driven through the structure-aware dispatcher.

For every (matrix x format x d) cell we measure wall-clock GFLOP/s of the
jitted SpMM (the paper's Table V) and compare attained performance against
the dispatcher's per-candidate prediction (bandwidth roofline ``beta * AI``
capped by the format compute ceiling).  One extra row per (matrix, d)
records ``strategy="auto"`` — the dispatcher's structure-driven choice —
so dispatch-policy regressions show up directly in the CSV.

Format applicability (ELL padding blow-up, BCSR dense-block inflation,
DIA band width) is the dispatcher's policy; skipped candidates are
reported with the dispatcher's own skip reasons rather than silence.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro import sparse
from repro.configs.paper_spmm import CONFIG as SPMM_CONFIG
from repro.core.hardware import HOST_CPU
from repro.core.patterns import paper_suite


def _time_call(fn, *args, repeats: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


@dataclasses.dataclass
class CellResult:
    matrix: str
    pattern: str
    impl: str                    # format name, or "auto"
    d: int
    nnz: int
    gflops: float
    ai_model: float              # candidate's sparsity-aware AI
    predicted_gflops: float      # dispatcher prediction (roofline + ceiling)
    roofline_fraction: float
    chosen: str                  # dispatcher's auto pick for this (matrix, d)
    dtype: str = "f32i32"        # storage-precision token the cell ran at


def make_dispatcher(beta: float, **kwargs) -> sparse.Dispatcher:
    """Dispatcher whose roofline uses the measured STREAM bandwidth."""
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=beta)
    return sparse.Dispatcher(hardware=hw, **kwargs)


def run_suite(beta: float, scale: int | None = None,
              d_values=None, impls=None, repeats=None,
              dispatcher: Optional[sparse.Dispatcher] = None,
              precision: Optional[str] = None) -> List[CellResult]:
    """Measure the (matrix x format x d) grid; one CSV row per cell.

    ``precision`` forces every cell onto one storage precision (e.g.
    ``"bf16i32"`` for the nightly bf16 lane); ``None`` runs the
    dispatcher's fp32 default.  The token lands in the ``dtype`` column
    so trend tooling never compares cells across precisions.
    """
    from repro.kernels import registry as kernel_registry
    cfg = SPMM_CONFIG
    scale = scale or cfg.scale
    d_values = d_values or cfg.d_values
    impls = impls or cfg.implementations
    repeats = repeats or cfg.repeats
    disp = dispatcher or make_dispatcher(beta, bcsr_block=cfg.bcsr_block)
    target_tok = (sparse.as_precision(precision).token
                  if precision is not None else "f32i32")
    # Only benchmark formats with a kernel registered for the resolved
    # backend (the same registry the dispatcher executes through).
    backend = disp._resolve_backend()
    impls = [f for f in impls
             if f in kernel_registry.formats_for(backend)]
    results: List[CellResult] = []
    rng = np.random.default_rng(0)

    provenance_reported = False
    for name, gen in paper_suite(scale).items():
        m = gen()
        first = disp.plan(m, d_values[0])
        for reported, reason in first.skips.items():
            print(f"# skip {reported} on {name}: {reason}")
        if not provenance_reported:
            provenance_reported = True
            srcs = sorted(set(first.ceiling_sources.values()))
            print(f"# compute ceilings: "
                  f"{ {f: s for f, s in sorted(first.ceiling_sources.items())} }"
                  if srcs != ["default"] else
                  "# compute ceilings: DEFAULT_EFFICIENCY (no calibration "
                  "for this HardwareSpec; run benchmarks/run.py --calibrate)")
        for d in d_values:
            b = np.asarray(rng.normal(size=(m.n, d)), dtype=np.float32)
            b = jax.numpy.asarray(b)
            plan = disp.plan(m, d, precision=precision)
            cells = [c for c in plan.candidates
                     if c.eligible and c.format in impls
                     and c.precision == target_tok]
            for cand in cells:
                dt = _time_call(
                    lambda mm, bb, s=cand.format: disp.spmm(
                        mm, bb, strategy=s, precision=precision),
                    m, b, repeats=repeats)
                gflops = 2.0 * m.nnz * d / dt / 1e9
                results.append(CellResult(
                    matrix=name, pattern=m.pattern, impl=cand.format, d=d,
                    nnz=m.nnz, gflops=gflops, ai_model=cand.ai,
                    predicted_gflops=cand.predicted_gflops,
                    roofline_fraction=gflops / cand.predicted_gflops,
                    chosen=plan.chosen, dtype=cand.precision))
            # The dispatcher's own pick, as its own row: the auto path must
            # keep up with the best fixed format (paper's thesis in action).
            auto = plan.candidate(plan.chosen)
            dt = _time_call(
                lambda mm, bb: disp.spmm(mm, bb, precision=precision),
                m, b, repeats=repeats)
            gflops = 2.0 * m.nnz * d / dt / 1e9
            results.append(CellResult(
                matrix=name, pattern=m.pattern, impl="auto", d=d,
                nnz=m.nnz, gflops=gflops, ai_model=auto.ai,
                predicted_gflops=auto.predicted_gflops,
                roofline_fraction=gflops / auto.predicted_gflops,
                chosen=plan.chosen, dtype=plan.precision))
    return results


def paper_claims_check(results: List[CellResult]) -> Dict[str, bool]:
    """The paper's qualitative claims, validated on our measurements.

    1. random sparsity is the slowest regime (Section IV-C)
    2. performance improves with d (lowest at d=1) (Section IV-C)
    3. structured (diagonal/blocked at large d) beats random (Fig. 1)
    4. blocked-regime BCSR approaches its roofline better than random-CSR
       approaches the random roofline upper bound region (Section IV-D)
    5. the dispatcher's auto choice keeps up with the best fixed format
       (the PR's structure-aware selection claim)
    """
    # Degree-~1 matrices (er_*_1, ideal_diagonal) have nnz ~ n: their B
    # gather fits in cache and the sub-ms kernel measures dispatch
    # overhead, not bandwidth — exclude them from *regime* aggregates
    # (they stay in the full table).  Threshold: nnz >= 4n.
    n_rows = {r.matrix: r.nnz for r in results}
    big = {m for m, nnz in n_rows.items()
           if nnz >= 4 * min(n_rows.values())}

    def mean_gf(pattern=None, impl=None, d=None, prefix=None):
        xs = [r.gflops for r in results
              if (pattern is None or r.pattern == pattern)
              and (impl is None or r.impl == impl)
              and (d is None or r.d == d)
              and (prefix is None or r.matrix.startswith(prefix))
              and r.matrix in big]
        return float(np.mean(xs)) if xs else float("nan")

    d_vals = sorted({r.d for r in results})
    by_d = [np.mean([r.gflops for r in results if r.d == d])
            for d in d_vals]
    # Regime comparisons use the CSR implementation (the common baseline,
    # like the paper's Fig. 1 trends); the dense-block claim uses the
    # FEM-style matrices where CSB/BCSR's layout is applicable.
    mid_d = d_vals[len(d_vals) // 2]
    claims = {
        # Structured (banded/blocked) locality beats random — strongest at
        # the paper's mid-range d where B reuse matters and the working
        # set still partially caches (paper Fig. 1 trends).
        "random_below_structured": (
            mean_gf("random", impl="csr", d=mid_d) <
            min(mean_gf("diagonal", impl="csr", d=mid_d),
                mean_gf("blocked", impl="csr", d=mid_d))),
        "perf_grows_with_d": by_d[0] == min(by_d),
        "structured_beats_random_at_large_d": (
            mean_gf("blocked", impl="csr", d=d_vals[-1]) >
            mean_gf("random", impl="csr", d=d_vals[-1]) * 0.9),
        "bcsr_best_on_dense_blocks": (
            mean_gf(impl="bcsr", prefix="fem") >=
            mean_gf(impl="csr", prefix="fem") * 0.8),
        # Paper: scale-free is the FASTEST regime (hub rows cache).  On
        # this 1-core XLA host the gather pipeline is instruction-bound,
        # not DRAM-bound, so we only assert parity with random; the
        # refuted stronger form is discussed in EXPERIMENTS.md.
        "scale_free_not_below_random": (
            mean_gf("scale_free", impl="csr") >=
            mean_gf("random", impl="csr") * 0.9),
    }
    claims.update(dispatch_claims_check(results))
    return claims


def auto_vs_best_fixed(results: List[CellResult]) -> Dict[str, float]:
    """Per matrix: auto throughput relative to the best *fixed* format.

    A fixed strategy must commit to one format per matrix across all d, so
    the comparison sums wall-clock over the d sweep: ratio =
    best_fixed_total_time / auto_total_time (>= 1 means auto wins).

    Auto executes the identical (format, kernel) pair as the fixed row it
    selected, so its per-d time is taken from that format's measured row
    (the separately timed "auto" row stays in the CSV for transparency but
    re-measuring the same kernel would only add noise to this ratio).
    """
    ratios: Dict[str, float] = {}
    for matrix in sorted({r.matrix for r in results}):
        rows = [r for r in results if r.matrix == matrix]
        d_vals = sorted({r.d for r in rows})

        def cell_time(r):
            return 2.0 * r.nnz * r.d / (r.gflops * 1e9)

        def total_time(impl):
            cells = {r.d: r for r in rows if r.impl == impl}
            if set(cells) != set(d_vals):
                return float("inf")
            return sum(cell_time(r) for r in cells.values())

        def auto_time():
            total = 0.0
            for d in d_vals:
                by_impl = {r.impl: r for r in rows if r.d == d}
                if "auto" not in by_impl:
                    return float("inf")
                r = by_impl.get(by_impl["auto"].chosen, by_impl["auto"])
                total += cell_time(r)
            return total

        fixed = [t for t in (total_time(i) for i in sparse.FORMATS)
                 if np.isfinite(t)]
        auto = auto_time()
        if fixed and np.isfinite(auto):
            ratios[matrix] = min(fixed) / auto
    return ratios


def dispatch_claims_check(results: List[CellResult]) -> Dict[str, bool]:
    """Structure-aware dispatch acceptance: right formats, no regression."""
    largest_d = max(r.d for r in results)
    chosen_at = {r.matrix: r.chosen for r in results if r.d == largest_d}

    def picks(prefixes, fmt):
        sel = [c for mname, c in chosen_at.items()
               if any(mname.startswith(p) for p in prefixes)]
        return bool(sel) and all(c == fmt for c in sel)

    # The throughput-ratio claim uses the same nnz >= 4 * min filter as the
    # regime claims: degree-~1 matrices run in tens of microseconds, where
    # this host's wall-clock noise (2x between identical runs) swamps any
    # real format difference.  Their rows stay in the CSV.
    nnzs = {r.matrix: r.nnz for r in results}
    big = {m for m, nnz in nnzs.items() if nnz >= 4 * min(nnzs.values())}
    ratios = {m: r for m, r in auto_vs_best_fixed(results).items()
              if m in big}
    def picks_any(prefixes, fmts):
        sel = [c for mname, c in chosen_at.items()
               if any(mname.startswith(p) for p in prefixes)]
        return bool(sel) and all(c in fmts for c in sel)

    return {
        "dispatch_banded_to_dia": picks(("ideal_diagonal", "band"), "dia"),
        "dispatch_fem_to_bcsr": picks(("fem",), "bcsr"),
        # Scale-free must land in the CSR gather family — plain CSR or one
        # of PR 8's reorderings of it (binned/rowsplit/ell_coo); which
        # member wins is a per-host ceiling question, not a policy one.
        "dispatch_scale_free_to_gather_family": picks_any(
            ("powerlaw",), ("csr", "binned", "rowsplit", "ell_coo")),
        "dispatch_auto_within_0.9_of_best": (
            bool(ratios) and min(ratios.values()) >= 0.9),
    }


def scale_free_claims_check(results: List[CellResult]) -> Dict[str, bool]:
    """PR 8's measured scale-free claim (soft-reported by the runner).

    The two-phase binned kernel should beat the plain CSR gather order on
    the *highest-skew* power-law matrices (``powerlaw_*_205``): hub
    columns make CSR's row-major gather thrash B, while slab binning
    fetches each B slab once.  On 1-core CI hosts the gather pipeline is
    instruction-bound rather than bandwidth-bound and the ordering
    difference can vanish into wall-clock noise, so ``benchmarks/run.py``
    prints this claim PASS/FAIL without failing the build — gated like
    the sharded tier's speedup target, with the model-level form asserted
    deterministically in ``tests/test_dispatch.py``.
    """
    high_skew = [r for r in results
                 if r.pattern == "scale_free" and "_205" in r.matrix]

    def mean_gf(impl):
        xs = [r.gflops for r in high_skew if r.impl == impl and r.d >= 16]
        return float(np.mean(xs)) if xs else float("nan")

    binned, csr = mean_gf("binned"), mean_gf("csr")
    return {
        "binned_beats_csr_on_high_skew_scale_free": bool(
            np.isfinite(binned) and np.isfinite(csr) and binned >= csr),
    }


def precision_claims_check(results: List[CellResult]) -> Dict[str, bool]:
    """The bf16 lane's measured claim (soft-reported by the runner).

    Reduced-precision storage halves the dominant per-nonzero traffic, so
    on bandwidth-bound cells the bf16 rows should at least keep up with
    their fp32 twins.  On 1-core CI hosts the gather pipeline is often
    instruction-bound and the dtype difference disappears into cast
    overhead, so — like ``scale_free_claims_check`` — the runner prints
    PASS/FAIL without failing the build; the model-level >=1.5x form is
    asserted deterministically in ``tests/test_dispatch.py``.

    Only evaluable on a result set carrying both dtypes (e.g. an fp32 run
    concatenated with the bf16 lane's); returns an empty dict otherwise.
    """
    def mean_gf(reduced: bool) -> float:
        xs = [r.gflops for r in results
              if r.impl in ("csr", "binned", "rowsplit", "ell_coo")
              and r.d >= 16
              and (r.dtype.startswith("bf16") == reduced)]
        return float(np.mean(xs)) if xs else float("nan")

    bf16, f32 = mean_gf(True), mean_gf(False)
    if not (np.isfinite(bf16) and np.isfinite(f32)):
        return {}
    return {"bf16_keeps_up_with_fp32_on_gather_family": bool(bf16 >= f32)}


#: Shared schema for the SpMM CSV artifacts (single-shot + streamed rows).
CSV_HEADER = ("matrix,pattern,impl,d,nnz,gflops,ai_model,"
              "predicted_gflops,roofline_fraction,chosen,dtype")


def to_csv(results: List[CellResult]) -> str:
    lines = [CSV_HEADER]
    for r in results:
        lines.append(f"{r.matrix},{r.pattern},{r.impl},{r.d},{r.nnz},"
                     f"{r.gflops:.4f},{r.ai_model:.5f},"
                     f"{r.predicted_gflops:.4f},"
                     f"{r.roofline_fraction:.4f},{r.chosen},{r.dtype}")
    return "\n".join(lines)
