"""Benchmark harness — one section per paper table/figure.

  stream      beta measurement (paper Section IV-B)
  calibrate   (--calibrate) on-host ceiling calibration: fit per-format
              (peak_fraction, d_half) from a microbenchmark sweep and
              persist per HardwareSpec fingerprint, so later dispatch
              predictions use measured ceilings instead of the baked-in
              DEFAULT_EFFICIENCY constants
  table5      SpMM GFLOP/s across formats x matrices x d, via the
              structure-aware dispatcher (plus one strategy="auto" row per
              cell)
  fig2        attained vs sparsity-aware roofline + paper-claims check
  serve       streamed vs per-call dispatch across the four structures
              (the sparse.plan serving path; rows appended to the SpMM CSV)
  shard       sharded vs single-device steady-state replay (the
              sparse.plan(mesh=...) tier); rows appended to the SpMM CSV
              with the chosen B-strategy in the impl column.  Run under
              XLA_FLAGS=--xla_force_host_platform_device_count=8 on CPU.
  engine      continuous-batching engine vs per-request sync replay
              (repro.sparse.engine): per-request p50/p99 latency and
              goodput per structure, written to its own latency CSV
              (engine_smoke.csv / engine_table.csv — latency columns,
              not the GFLOP/s schema).  ``--engine-smoke`` runs it alone
              and enforces the coalescing-beats-sync goodput claim.
  kernels     Pallas kernel wall-time (interpret mode; correctness-scale)
  roofline    per-(arch x shape x mesh) three-term table from the dry-run
              records in experiments/dryrun (if present)

Prints ``name,us_per_call,derived`` CSV rows plus the full SpMM CSV to
benchmarks/out/.  ``--smoke`` runs the SpMM + streamed-serving suites at
tiny scale with few repeats — the CI per-PR dispatch-policy and
plan-once-beats-percall regression checks; the produced CSV (including
the streamed rows) is uploaded as a workflow artifact.  ``--smoke-bf16``
re-runs the tiny suite at reduced storage precision (bf16 values) and
soft-reports the bf16-vs-fp32 comparison — the CI nightly bf16 lane.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time


def _emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}")


def bench_stream() -> float:
    from benchmarks.stream import measure_bandwidth
    t0 = time.perf_counter()
    bw = measure_bandwidth(n_bytes=128 * 2 ** 20, repeats=3)
    _emit("stream.copy", (time.perf_counter() - t0) * 1e6,
          f"{bw['copy'] / 1e9:.2f}GB/s")
    _emit("stream.triad", (time.perf_counter() - t0) * 1e6,
          f"{bw['triad'] / 1e9:.2f}GB/s")
    return bw["triad"]


def bench_calibrate(beta: float) -> None:
    import dataclasses
    from repro.core.calibrate import CalibrationStore, calibrate
    from repro.core.hardware import HOST_CPU
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=beta)
    store = CalibrationStore()
    t0 = time.perf_counter()
    cal = calibrate(hw, backend="jax", store=store)
    _emit("calibrate.total", (time.perf_counter() - t0) * 1e6,
          f"saved={store.path_for(hw)}")
    for e in cal.entries:
        _emit(f"calibrate.{e.format}", 0.0,
              f"peak_fraction={e.peak_fraction:.4f};d_half={e.d_half:.1f};"
              f"sustained={e.sustained_gflops:.2f}GF/s")


def bench_spmm(beta: float, *, scale: int = 16, d_values=None,
               repeats=None, csv_name: str = "table5_spmm.csv",
               dispatch_claims_only: bool = False) -> None:
    from benchmarks.spmm_suite import (
        dispatch_claims_check, paper_claims_check, run_suite,
        scale_free_claims_check, to_csv)
    # scale=16 (n=65,536): B and C at d=64 are 16 MB each, so the working
    # set exceeds this host's LLC — the paper's out-of-cache regime
    # (Section IV-A "matrices were selected to exceed on-chip caches").
    # The regime-comparison claims only hold out-of-cache, so smoke runs
    # (tiny, in-cache) check the dispatch claims alone.
    results = run_suite(beta, scale=scale, d_values=d_values,
                        repeats=repeats)
    os.makedirs("benchmarks/out", exist_ok=True)
    with open(os.path.join("benchmarks/out", csv_name), "w") as f:
        f.write(to_csv(results))
    for r in results:
        if r.d in (1, 64):
            _emit(f"table5.{r.matrix}.{r.impl}.d{r.d}",
                  2.0 * r.nnz * r.d / max(r.gflops, 1e-9) / 1e3,
                  f"{r.gflops:.2f}GF/s;roof={r.roofline_fraction:.2f};"
                  f"chosen={r.chosen}")
    claims = (dispatch_claims_check(results) if dispatch_claims_only
              else paper_claims_check(results))
    failed = [k for k, v in claims.items() if not v]
    for k, v in claims.items():
        _emit(f"fig2.claim.{k}", 0.0, "PASS" if v else "FAIL")
    # Soft-report (like the shard speedup target): the measured
    # binned-vs-CSR ordering needs a bandwidth-bound host; CI boxes are
    # instruction-bound, so this prints but never fails the build.
    for k, v in scale_free_claims_check(results).items():
        _emit(f"fig2.claim.{k}", 0.0, "PASS" if v else "FAIL")
    if dispatch_claims_only and failed:
        raise SystemExit(f"dispatch claims failed: {failed}")


def bench_spmm_bf16(beta: float, *, scale: int = 11, d_values=(16, 64),
                    repeats: int = 3,
                    csv_name: str = "smoke_spmm_bf16.csv") -> None:
    """bf16 smoke lane: the tiny suite re-run at reduced storage precision.

    CPU CI emulates bf16 (XLA upcasts to fp32 on host), so measured
    GFLOP/s carry no claim weight here; the lane exercises the
    reduced-precision dispatch path end-to-end nightly and gives the
    bf16-keyed cells their own trend baseline (``tools/perf_trend.py``
    keys cells on the dtype column, so these rows never diff against
    fp32 ones).  The bf16-keeps-up-with-fp32 comparison is soft-reported
    over the combined fp32 + bf16 results, mirroring the scale-free
    ordering soft report.  The jax backend carries bf16 with int32
    indices (XLA gathers), so the lane pins ``precision="bf16i32"``.
    """
    from benchmarks.spmm_suite import (
        precision_claims_check, run_suite, to_csv)
    base = run_suite(beta, scale=scale, d_values=d_values, repeats=repeats)
    reduced = run_suite(beta, scale=scale, d_values=d_values,
                        repeats=repeats, precision="bf16i32")
    os.makedirs("benchmarks/out", exist_ok=True)
    with open(os.path.join("benchmarks/out", csv_name), "w") as f:
        f.write(to_csv(reduced))
    for r in reduced:
        if r.d == max(d_values):
            _emit(f"bf16.{r.matrix}.{r.impl}.d{r.d}",
                  2.0 * r.nnz * r.d / max(r.gflops, 1e-9) / 1e3,
                  f"{r.gflops:.2f}GF/s;dtype={r.dtype};chosen={r.chosen}")
    for k, v in precision_claims_check(base + reduced).items():
        _emit(f"fig2.claim.{k}", 0.0, "PASS" if v else "FAIL")


def bench_stream_suite(beta: float, *, scale: int, d_values, reuses,
                       repeats: int, csv_name: str,
                       enforce: bool = False) -> None:
    from benchmarks.spmm_suite import CSV_HEADER
    from benchmarks.stream import (
        run_stream_suite, stream_claims_check, to_csv_rows)
    cells = run_stream_suite(beta, scale=scale, d_values=d_values,
                             reuses=reuses, repeats=repeats)
    path = os.path.join("benchmarks/out", csv_name)
    os.makedirs("benchmarks/out", exist_ok=True)
    # Appended to the SpMM CSV: one artifact per run, streamed rows keyed
    # by their impl column (stream_r8 / percall_r8 / ...).  Start from the
    # shared header when this suite runs first / alone.
    fresh = not os.path.exists(path)
    with open(path, "a") as f:
        f.write((CSV_HEADER if fresh else "") + "\n"
                + "\n".join(to_csv_rows(cells)))
    for c in cells:
        if c.reuse >= 8:
            # us_per_call column: amortized per-RHS time (total includes
            # that mode's planning/conversion); total stays in derived.
            _emit(f"serve.{c.matrix}.{c.mode}.d{c.d}.r{c.reuse}",
                  c.total_s * 1e6 / c.reuse,
                  f"{c.gflops:.2f}GF/s;total={c.total_s * 1e3:.1f}ms;"
                  f"chosen={c.chosen}")
    claims = stream_claims_check(cells)
    failed = [k for k, v in claims.items() if not v]
    for k, v in claims.items():
        _emit(f"serve.claim.{k}", 0.0, "PASS" if v else "FAIL")
    if enforce and failed:
        raise SystemExit(f"streamed-dispatch claims failed: {failed}")


def bench_shard_suite(beta: float, *, scale: int, d_values,
                      repeats: int, csv_name: str) -> None:
    from benchmarks.spmm_suite import CSV_HEADER
    from benchmarks.stream import (
        run_shard_suite, shard_claims_check, shard_csv_rows)
    cells = run_shard_suite(beta, scale=scale, d_values=d_values,
                            repeats=repeats)
    path = os.path.join("benchmarks/out", csv_name)
    os.makedirs("benchmarks/out", exist_ok=True)
    fresh = not os.path.exists(path)
    with open(path, "a") as f:
        f.write((CSV_HEADER if fresh else "") + "\n"
                + "\n".join(shard_csv_rows(cells)))
    for c in cells:
        _emit(f"shard.{c.matrix}.{c.impl}.d{c.d}",
              c.steady_s * 1e6,
              f"{c.gflops:.2f}GF/s;devices={c.devices};"
              f"speedup={c.speedup:.2f};chosen={c.chosen}")
    # Soft-report: the >=1.5x target needs real cores behind the virtual
    # devices (see shard_claims_check); the CSV rows carry the measured
    # speedups either way, and tools/perf_trend.py tracks them per-cell.
    for k, v in shard_claims_check(cells).items():
        _emit(f"shard.claim.{k}", 0.0, "PASS" if v else "FAIL")


def bench_engine_suite(beta: float, *, scale: int, d: int, streams: int,
                       per_stream: int, repeats: int, csv_name: str,
                       enforce: bool = False) -> None:
    from benchmarks.stream import (
        ENGINE_CSV_HEADER, engine_claims_check, engine_csv_rows,
        run_engine_suite)
    cells = run_engine_suite(beta, scale=scale, d=d, streams=streams,
                             per_stream=per_stream, repeats=repeats)
    os.makedirs("benchmarks/out", exist_ok=True)
    # The engine lane gets its own CSV: latency/goodput columns, not the
    # GFLOP/s schema the other lanes share.  tools/perf_trend.py trends
    # it with --metric goodput_rps.
    with open(os.path.join("benchmarks/out", csv_name), "w") as f:
        f.write(ENGINE_CSV_HEADER + "\n" + "\n".join(engine_csv_rows(cells)))
    for c in cells:
        _emit(f"engine.{c.matrix}.{c.impl}.d{c.d}", c.p50_us,
              f"p99={c.p99_us:.0f}us;goodput={c.goodput_rps:.1f}rps;"
              f"batches={c.batches}")
    claims = engine_claims_check(cells)
    failed = [k for k, v in claims.items() if not v]
    for k, v in claims.items():
        _emit(f"engine.claim.{k}", 0.0, "PASS" if v else "FAIL")
    if enforce and failed:
        raise SystemExit(f"serving-engine claims failed: {failed}")


def bench_kernels() -> None:
    import jax.numpy as jnp
    import numpy as np
    import jax
    from repro import kernels, sparse
    from repro.core import blocked as gen_blocked
    from repro.core import erdos_renyi
    from repro.kernels import registry
    m = gen_blocked(512, t=32, num_blocks=120, nnz_per_block=60, seed=0)
    b = jnp.asarray(np.random.default_rng(0).normal(
        size=(512, 64)).astype(np.float32))
    # Registry path (the ops.py wrappers are deprecated): bind prepares
    # the layout once, then timing measures the kernel replay alone.
    ctx = registry.KernelContext(bcsr_block=32, row_tile=8, chunk=128)
    run_bcsr = registry.get("bcsr", "pallas").bind(m, ctx)
    jax.block_until_ready(run_bcsr(b))
    t0 = time.perf_counter()
    jax.block_until_ready(run_bcsr(b))
    us = (time.perf_counter() - t0) * 1e6
    roof = kernels.bcsr_kernel_roofline(sparse.coo_to_bcsr(m, 32), 64)
    _emit("kernels.bcsr_spmm.interp", us,
          f"ai={roof.ai:.2f};mxu_util={roof.mxu_utilization:.2f}")
    mc = erdos_renyi(512, 8, seed=1)
    run_csr = registry.get("csr", "pallas").bind(mc, ctx)
    jax.block_until_ready(run_csr(b))
    t0 = time.perf_counter()
    jax.block_until_ready(run_csr(b))
    us = (time.perf_counter() - t0) * 1e6
    roof = kernels.csr_kernel_roofline(sparse.coo_to_csr(mc), 64)
    _emit("kernels.csr_spmm.interp", us,
          f"ai={roof.ai:.2f};mxu_util={roof.mxu_utilization:.2f}")
    g = kernels.grouped_matmul_roofline(4096, 4096, 1536, 128)
    _emit("kernels.grouped_matmul.model", 0.0,
          f"ai={g.ai:.1f};attainable={g.attainable_flops_per_s/1e12:.0f}TF")


def bench_roofline_table() -> None:
    from repro.core.analyzer import analyze_record
    paths = sorted(glob.glob("experiments/dryrun/*.json"))
    if not paths:
        _emit("roofline.table", 0.0, "SKIP-no-dryrun-records")
        return
    for p in paths:
        rec = analyze_record(json.load(open(p)))
        r = rec["roofline"]
        _emit(f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
              r["step_time_lower_bound_s"] * 1e6,
              f"dom={r['dominant']};mfu_ceil={r['mfu_upper_bound']:.3f}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny-scale SpMM suite only (CI per-PR check); "
                             "writes benchmarks/out/smoke_spmm.csv")
    parser.add_argument("--engine-smoke", action="store_true",
                        help="engine-vs-sync serving lane only (CI engine "
                             "smoke job); writes benchmarks/out/"
                             "engine_smoke.csv and enforces the "
                             "coalescing-beats-sync goodput claim")
    parser.add_argument("--smoke-bf16", action="store_true",
                        help="tiny-scale suite at reduced storage "
                             "precision (CI nightly bf16 lane); writes "
                             "benchmarks/out/smoke_spmm_bf16.csv and "
                             "soft-reports the bf16-vs-fp32 comparison")
    parser.add_argument("--calibrate", action="store_true",
                        help="fit + persist on-host per-format compute "
                             "ceilings before (or instead of) the suites; "
                             "subsequent dispatcher predictions use them")
    args = parser.parse_args()
    print("name,us_per_call,derived")
    beta = bench_stream()
    if args.calibrate:
        bench_calibrate(beta)
        if not args.smoke:
            return
    if args.smoke_bf16:
        bench_spmm_bf16(beta)
        return
    if args.engine_smoke:
        bench_engine_suite(beta, scale=10, d=8, streams=4, per_stream=8,
                           repeats=3, csv_name="engine_smoke.csv",
                           enforce=True)
        return
    if args.smoke:
        bench_spmm(beta, scale=11, d_values=(1, 16, 64), repeats=3,
                   csv_name="smoke_spmm.csv", dispatch_claims_only=True)
        bench_stream_suite(beta, scale=10, d_values=(16, 64),
                           reuses=(1, 8), repeats=2,
                           csv_name="smoke_spmm.csv", enforce=True)
        bench_shard_suite(beta, scale=10, d_values=(64,), repeats=3,
                          csv_name="smoke_spmm.csv")
        return
    bench_spmm(beta)
    bench_stream_suite(beta, scale=12, d_values=(16, 64),
                       reuses=(1, 8, 64), repeats=2,
                       csv_name="table5_spmm.csv")
    bench_shard_suite(beta, scale=12, d_values=(16, 64), repeats=3,
                      csv_name="table5_spmm.csv")
    bench_engine_suite(beta, scale=12, d=8, streams=4, per_stream=16,
                       repeats=3, csv_name="engine_table.csv")
    bench_kernels()
    bench_roofline_table()


if __name__ == "__main__":
    main()
