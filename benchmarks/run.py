"""Benchmark harness — one section per paper table/figure.

  stream      beta measurement (paper Section IV-B)
  table5      SpMM GFLOP/s across implementations x matrices x d
  fig2        attained vs sparsity-aware roofline + paper-claims check
  kernels     Pallas kernel wall-time (interpret mode; correctness-scale)
  roofline    per-(arch x shape x mesh) three-term table from the dry-run
              records in experiments/dryrun (if present)

Prints ``name,us_per_call,derived`` CSV rows plus the full SpMM CSV to
benchmarks/out/.
"""
from __future__ import annotations

import glob
import json
import os
import time


def _emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}")


def bench_stream() -> float:
    from benchmarks.stream import measure_bandwidth
    t0 = time.perf_counter()
    bw = measure_bandwidth(n_bytes=128 * 2 ** 20, repeats=3)
    _emit("stream.copy", (time.perf_counter() - t0) * 1e6,
          f"{bw['copy'] / 1e9:.2f}GB/s")
    _emit("stream.triad", (time.perf_counter() - t0) * 1e6,
          f"{bw['triad'] / 1e9:.2f}GB/s")
    return bw["triad"]


def bench_spmm(beta: float) -> None:
    from benchmarks.spmm_suite import paper_claims_check, run_suite, to_csv
    # scale=16 (n=65,536): B and C at d=64 are 16 MB each, so the working
    # set exceeds this host's LLC — the paper's out-of-cache regime
    # (Section IV-A "matrices were selected to exceed on-chip caches").
    results = run_suite(beta, scale=16)
    os.makedirs("benchmarks/out", exist_ok=True)
    with open("benchmarks/out/table5_spmm.csv", "w") as f:
        f.write(to_csv(results))
    for r in results:
        if r.d in (1, 64):
            _emit(f"table5.{r.matrix}.{r.impl}.d{r.d}",
                  2.0 * r.nnz * r.d / max(r.gflops, 1e-9) / 1e3,
                  f"{r.gflops:.2f}GF/s;roof={r.roofline_fraction:.2f}")
    claims = paper_claims_check(results)
    for k, v in claims.items():
        _emit(f"fig2.claim.{k}", 0.0, "PASS" if v else "FAIL")


def bench_kernels() -> None:
    import jax.numpy as jnp
    import numpy as np
    import jax
    from repro import kernels, sparse
    from repro.core import blocked as gen_blocked
    m = gen_blocked(512, t=32, num_blocks=120, nnz_per_block=60, seed=0)
    a = sparse.coo_to_bcsr(m, 32)
    b = jnp.asarray(np.random.default_rng(0).normal(
        size=(512, 64)).astype(np.float32))
    out = kernels.bcsr_spmm(a, b, block_d=64)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    jax.block_until_ready(kernels.bcsr_spmm(a, b, block_d=64))
    us = (time.perf_counter() - t0) * 1e6
    roof = kernels.bcsr_kernel_roofline(a, 64)
    _emit("kernels.bcsr_spmm.interp", us,
          f"ai={roof.ai:.2f};mxu_util={roof.mxu_utilization:.2f}")
    g = kernels.grouped_matmul_roofline(4096, 4096, 1536, 128)
    _emit("kernels.grouped_matmul.model", 0.0,
          f"ai={g.ai:.1f};attainable={g.attainable_flops_per_s/1e12:.0f}TF")


def bench_roofline_table() -> None:
    from repro.core.analyzer import analyze_record
    paths = sorted(glob.glob("experiments/dryrun/*.json"))
    if not paths:
        _emit("roofline.table", 0.0, "SKIP-no-dryrun-records")
        return
    for p in paths:
        rec = analyze_record(json.load(open(p)))
        r = rec["roofline"]
        _emit(f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
              r["step_time_lower_bound_s"] * 1e6,
              f"dom={r['dominant']};mfu_ceil={r['mfu_upper_bound']:.3f}")


def main() -> None:
    print("name,us_per_call,derived")
    beta = bench_stream()
    bench_spmm(beta)
    bench_kernels()
    bench_roofline_table()


if __name__ == "__main__":
    main()
