"""Checkpointer: roundtrip, atomicity, retention, elastic restore."""
import json
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(4, 4)),
                                        jnp.float32),
                       "b": jnp.asarray(rng.normal(size=4), jnp.float32)},
            "opt": {"count": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(3, tree)
    out = ck.restore()
    np.testing.assert_array_equal(out["params"]["w"],
                                  np.asarray(tree["params"]["w"]))
    assert int(out["opt"]["count"]) == 7
    assert ck.latest_step() == 3


def test_atomicity_ignores_uncommitted(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1))
    # Simulate a crash mid-save at step 2: directory without sentinel.
    d = ck._dir(2)
    shutil.copytree(ck._dir(1), d)
    os.remove(os.path.join(d, "COMMITTED"))
    assert ck.latest_step() == 1
    # And a stale tmp dir is invisible too.
    shutil.copytree(ck._dir(1), ck._dir(3) + ".tmp")
    assert ck.latest_step() == 1


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    assert ck.committed_steps() == [3, 4]


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore()


def test_restore_validates_structure(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": {"w": jnp.ones(2)}})
    with pytest.raises(ValueError):
        ck.restore(like={"params": {"w": jnp.ones(2),
                                    "missing": jnp.ones(2)}})


def test_manifest_contents(tmp_path):
    ck = Checkpointer(str(tmp_path))
    path = ck.save(5, _tree())
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == 5
    assert manifest["arrays"]["params/w"]["shape"] == [4, 4]
