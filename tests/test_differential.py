"""Cross-kernel differential suite: every registered (format, backend)
SpMM pair against the dense reference.

This is the pin for the kernel-authoring contract (``docs/kernels.md``):
any KernelSpec whose ``operand`` is ``"coo"`` must take an arbitrary
square ``COOMatrix`` — including degenerate ones — and compute
``C = A @ B`` for any ``d >= 1``.  The suite sweeps

  * structure classes the dispatcher targets (banded / blocked /
    scale-free / uniform), sampled property-style via ``hypothesis``
    (or the deterministic stub on stripped hosts);
  * adversarial shapes: the empty matrix, all-empty rows, a single
    dense row among empty ones, singleton (degree-1) rows, n=1.

A format converter may reject a matrix with ``ValueError`` (e.g. BCSR's
divisibility gate) — that is a recorded skip, not a failure; CSR-family
pairs must never skip, so the suite cannot silently pass by rejecting
everything.  New kernels registered against the registry are picked up
automatically — there is nothing to update here when one is added.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                               # stripped environment
    from _hypothesis_stub import given, settings, st

from repro import sparse
from repro.core.hardware import HOST_CPU
from repro.core.patterns import (
    COOMatrix, banded, blocked, erdos_renyi, scale_free)
from repro.kernels import registry
from repro.sparse import formats as fmt

#: Every registered pair that speaks the COO SpMM contract.  Specs with
#: another operand (the MoE grouped matmul) are excluded by their own
#: declaration, not by name.
PAIRS = [(s.format, s.backend) for s in registry.specs()
         if s.operand == "coo"]

#: Pairs that must never ValueError-skip: CSR itself and the layouts
#: that start from CSR order (they accept any square COOMatrix).
NEVER_SKIP = {"csr", "binned", "rowsplit", "ell_coo"}

RTOL = ATOL = 5e-4


def _ctx() -> registry.KernelContext:
    # bcsr_block=8 so blocked structures at test sizes clear the
    # divisibility gate; interpret resolves to True off-TPU.
    return registry.KernelContext(hardware=HOST_CPU, bcsr_block=8)


def _check_all_pairs(m: COOMatrix, d: int, seed: int = 0) -> None:
    """Assert every registered COO pair matches the dense reference."""
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.normal(size=(m.n, d)).astype(np.float32))
    ref = np.asarray(fmt.coo_to_dense(m)) @ np.asarray(b)
    ctx = _ctx()
    failures, skips = [], {}
    for format, backend in PAIRS:
        try:
            out = registry.spmm(m, b, format=format, backend=backend,
                                ctx=ctx)
        except ValueError as e:       # converter policy gate: recorded skip
            skips[(format, backend)] = str(e)
            continue
        if not np.allclose(np.asarray(out), ref, rtol=RTOL, atol=ATOL):
            err = float(np.max(np.abs(np.asarray(out) - ref)))
            failures.append(f"{format}/{backend}: max|err|={err:.3e}")
    assert not failures, (
        f"kernels diverge from dense reference on {m.pattern} "
        f"(n={m.n}, nnz={m.nnz}, d={d}): {failures}")
    for (format, backend), reason in skips.items():
        assert format not in NEVER_SKIP, (
            f"{format}/{backend} must accept any matrix but skipped: "
            f"{reason}")
        assert reason                 # a skip always carries its reason


def test_registered_pair_coverage():
    """The suite must actually cover the full dispatch surface: every
    dispatcher format on both backends (else a green run means nothing)."""
    assert set(PAIRS) >= {(f, b) for f in sparse.FORMATS
                          for b in registry.BACKENDS}
    assert ("grouped", "pallas") not in PAIRS     # operand="moe" excluded


# --------------------------------------------------------------------- #
# Structure classes, property-style.  n stays in a small fixed set so
# jit caches hit across examples; d=1 / odd d exercise the kernels'
# d-padding paths.
# --------------------------------------------------------------------- #

def _structured(structure: str, n: int, seed: int) -> COOMatrix:
    if structure == "banded":
        return banded(n, bandwidth=min(3, n - 1), fill=0.8, seed=seed)
    if structure == "block":
        return blocked(n, t=8, num_blocks=max(1, n // 8),
                       nnz_per_block=20, seed=seed)
    if structure == "scale_free":
        return scale_free(n, 4, alpha=2.05, seed=seed)
    return erdos_renyi(n, 4, seed=seed)           # uniform


@settings(max_examples=20, deadline=None)
@given(structure=st.sampled_from(("banded", "block", "scale_free",
                                  "uniform")),
       n=st.sampled_from((8, 24, 64)),
       d=st.sampled_from((1, 8, 33)),
       seed=st.integers(0, 4))
def test_all_pairs_match_dense_on_structures(structure, n, d, seed):
    _check_all_pairs(_structured(structure, n, seed), d, seed=seed)


# --------------------------------------------------------------------- #
# Adversarial shapes: the degenerate matrices a packer gets wrong first.
# --------------------------------------------------------------------- #

def _coo(n, rows, cols, vals=None) -> COOMatrix:
    rows = np.asarray(rows, dtype=np.int32)
    cols = np.asarray(cols, dtype=np.int32)
    if vals is None:
        vals = 1.0 + np.arange(rows.shape[0], dtype=np.float32)
    return COOMatrix(n=n, rows=rows, cols=cols,
                     vals=np.asarray(vals, dtype=np.float32),
                     pattern="adversarial")


ADVERSARIAL = {
    "all_zero": _coo(16, [], []),
    "n1_empty": _coo(1, [], []),
    "n1_dense": _coo(1, [0], [0]),
    # One hub row owning every column; every other row empty (the
    # rowsplit window and the binned visit map at their most skewed).
    "single_dense_row": _coo(16, [3] * 16, range(16)),
    # Exactly one nonzero per row (degree-1 permutation): chunks span
    # the maximum number of distinct rows.
    "singleton_rows": _coo(24, range(24),
                           np.random.default_rng(0).permutation(24)),
    # Alternating empty rows: row ids are non-contiguous in every chunk.
    "empty_rows": _coo(32, [r for r in range(32) if r % 2 == 0] * 2,
                       list(range(0, 32, 2)) + list(range(1, 32, 2))),
    # Last row/col only: boundary slabs and partial row tiles.
    "corner": _coo(17, [16, 16, 0], [16, 0, 16]),
}


@pytest.mark.parametrize("case", sorted(ADVERSARIAL))
@pytest.mark.parametrize("d", [1, 8])
def test_all_pairs_match_dense_on_adversarial(case, d):
    _check_all_pairs(ADVERSARIAL[case], d)


def test_forced_dispatch_agrees_with_differential_reference():
    """End-to-end: forcing each always-eligible format through the
    dispatcher (the path users hit) equals the dense reference too."""
    m = scale_free(64, 4, alpha=2.1, seed=7)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.normal(size=(m.n, 8)).astype(np.float32))
    ref = np.asarray(fmt.coo_to_dense(m)) @ np.asarray(b)
    for strategy in sorted(NEVER_SKIP):
        out = sparse.spmm(m, b, strategy=strategy)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=RTOL,
                                   atol=ATOL, err_msg=strategy)


# --------------------------------------------------------------------- #
# Real-matrix sweep: every vendored corpus file through every pair.
# The loaders feed the same COO contract the generators do, so a parsing
# bug (1-based indices, symmetric mirroring, square padding) shows up
# here as a numeric divergence, not a silent misload.
# --------------------------------------------------------------------- #

from repro.data import corpus as _corpus  # noqa: E402


@pytest.mark.parametrize(
    "entry", _corpus.vendored_entries(),
    ids=lambda e: f"{e.group}__{e.name}")
@pytest.mark.parametrize("d", [1, 8])
def test_all_pairs_match_dense_on_vendored_corpus(entry, d):
    _check_all_pairs(entry.load(), d)


# --------------------------------------------------------------------- #
# Precision sweep: every (format, backend) pair at every Precision it
# declares, against the float64 dense reference.  The tolerance is the
# accumulation-contract bound, not a flat constant: products round at
# the operand dtype and accumulate in fp32, so the elementwise error is
# bounded by O(eps_dtype * (|A| @ |B|)).  A flat bf16 tolerance would
# either mask real packing bugs on small magnitudes or flake on hub
# rows; the elementwise bound does neither.
# --------------------------------------------------------------------- #

def _check_all_pairs_at_precision(m: COOMatrix, d: int,
                                  prec: sparse.Precision,
                                  seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    b = np.asarray(rng.normal(size=(m.n, d)).astype(np.float32))
    dense = np.asarray(fmt.coo_to_dense(m), np.float64)
    ref = dense @ b.astype(np.float64)
    # 4x headroom: A rounds once, B rounds once, each product rounds
    # once, and the output casts back to b.dtype once.
    bound = (4.0 * prec.eps * (np.abs(dense) @ np.abs(b).astype(np.float64))
             + ATOL + RTOL * np.abs(ref))
    ctx = registry.KernelContext(hardware=HOST_CPU, bcsr_block=8,
                                 precision=prec)
    bj = jnp.asarray(b)
    covered = 0
    for format, backend in PAIRS:
        if not registry.get(format, backend).supports_precision(prec):
            continue                  # declared unsupported: not a skip
        try:
            out = registry.spmm(m, bj, format=format, backend=backend,
                                ctx=ctx)
        except ValueError as e:       # converter policy gate
            assert format not in NEVER_SKIP, (
                f"{format}/{backend} skipped at {prec.token}: {e}")
            continue
        err = np.abs(np.asarray(out, np.float64) - ref)
        worst = float(np.max(err - bound)) if err.size else 0.0
        assert np.all(err <= bound), (
            f"{format}/{backend} at {prec.token} exceeds the "
            f"eps-scaled bound on {m.pattern} (n={m.n}, d={d}) by "
            f"{worst:.3e}")
        covered += 1
    # The CSR-family pallas kernels declare all three precisions, so a
    # sweep that covers nothing means the registry surface regressed.
    assert covered > 0, f"no pair ran precision {prec.token}"


@settings(max_examples=24, deadline=None)
@given(structure=st.sampled_from(("banded", "block", "scale_free",
                                  "uniform")),
       prec=st.sampled_from(sparse.PRECISIONS),
       d=st.sampled_from((1, 8, 33)),
       seed=st.integers(0, 4))
def test_all_pairs_match_dense_at_declared_precisions(structure, prec, d,
                                                      seed):
    _check_all_pairs_at_precision(_structured(structure, 24, seed), d,
                                  prec, seed=seed)


@pytest.mark.parametrize("case", sorted(ADVERSARIAL))
def test_all_pairs_match_dense_on_adversarial_bf16_i16(case):
    _check_all_pairs_at_precision(ADVERSARIAL[case], 8,
                                  sparse.PRECISION_BF16)


# --------------------------------------------------------------------- #
# int16 extent legality at the boundary.  The packers reserve sentinel
# slots equal to the extent itself, so the extent — not extent - 1 —
# must be representable: 2**15 - 1 is the largest legal extent and
# exactly 2**15 is illegal.
# --------------------------------------------------------------------- #

@settings(max_examples=40, deadline=None)
@given(extent=st.integers(2 ** 15 - 8, 2 ** 15 + 8))
def test_int16_extent_legality_at_boundary(extent):
    from repro.kernels.csr_spmm import index_extent_check
    legal = extent <= sparse.INT16_MAX_EXTENT
    assert sparse.int16_extent_ok(extent) == legal
    assert sparse.PRECISION_BF16.index_ok(extent) == legal
    # int32 never gates at this scale.
    assert sparse.PRECISION_BF16_I32.index_ok(extent)
    index_extent_check(extent, np.int32)          # never raises
    if legal:
        index_extent_check(extent, np.int16)
    else:
        with pytest.raises(ValueError, match="int16"):
            index_extent_check(extent, np.int16)
