"""Test bootstrap: make ``repro`` (src layout) and ``benchmarks``
importable regardless of how pytest is invoked."""
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)
