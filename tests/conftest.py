"""Test bootstrap: make ``repro`` (src layout) and ``benchmarks``
importable regardless of how pytest is invoked, and isolate the
calibration store so dispatch predictions never depend on whatever
``~/.cache/repro/calibrations`` happens to hold on the host."""
import os
import sys
import tempfile
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

os.environ["REPRO_CALIBRATION_DIR"] = tempfile.mkdtemp(
    prefix="repro-cal-test-")

# Hypothesis profiles (no-op on stripped hosts where only the stub in
# tests/_hypothesis_stub.py is available): "default" keeps tier-1 fast;
# "nightly" is the CI fuzz lane's budget, selected with
# ``--hypothesis-profile=nightly`` (falsifying examples persist under
# .hypothesis/ and are uploaded as artifacts by the workflow).
try:
    from hypothesis import HealthCheck, settings as _hyp_settings
except ImportError:
    pass
else:
    _hyp_settings.register_profile("default", max_examples=25,
                                   deadline=None)
    _hyp_settings.register_profile(
        "nightly", max_examples=400, deadline=None,
        print_blob=True,
        suppress_health_check=[HealthCheck.too_slow])
    _hyp_settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "default"))
