"""Test bootstrap: make ``repro`` (src layout) and ``benchmarks``
importable regardless of how pytest is invoked, and isolate the
calibration store so dispatch predictions never depend on whatever
``~/.cache/repro/calibrations`` happens to hold on the host."""
import os
import sys
import tempfile
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

os.environ["REPRO_CALIBRATION_DIR"] = tempfile.mkdtemp(
    prefix="repro-cal-test-")
