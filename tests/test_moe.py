"""MoE dispatch: oracle equivalence, capacity dropping, gradients,
and the multi-device shard_map path (subprocess with 8 host devices)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe

# Whole-module integration tests: excluded from tier-1 (run nightly / -m slow).
pytestmark = pytest.mark.slow


def _setup(E=8, k=2, d=16, ff=32, B=2, S=16, seed=0):
    params = moe.init_moe(jax.random.PRNGKey(seed), d, ff, E)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, d),
                          jnp.float32)
    return params, x


def test_local_matches_dense_oracle_no_drops():
    params, x = _setup()
    out1 = moe.moe_ffn(params, x, k=2, num_experts=8, capacity_factor=8.0)
    out2 = moe.moe_ffn_dense(params, x, k=2, num_experts=8)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-3, atol=2e-3)


def test_capacity_drops_tokens():
    params, x = _setup(B=1, S=64)
    full = moe.moe_ffn(params, x, k=2, num_experts=8, capacity_factor=8.0)
    tight = moe.moe_ffn(params, x, k=2, num_experts=8,
                        capacity_factor=0.25)
    # Dropping changes outputs but keeps them finite.
    assert np.isfinite(np.asarray(tight)).all()
    assert not np.allclose(np.asarray(full), np.asarray(tight))


def test_router_normalizes_topk():
    params, x = _setup()
    w, ids = moe._router(params["router"], x.reshape(-1, 16), 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(ids.max()) < 8


def test_gradients_flow_to_all_weight_kinds():
    params, x = _setup()

    def loss(p):
        return jnp.sum(moe.moe_ffn(p, x, k=2, num_experts=8,
                                   capacity_factor=8.0) ** 2)

    g = jax.grad(loss)(params)
    for key in ("router", "w_gate", "w_up", "w_down"):
        leaf_sum = jax.tree_util.tree_reduce(
            lambda a, b: a + float(jnp.sum(jnp.abs(b))), g[key], 0.0)
        assert leaf_sum > 0, key


_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models import moe
from repro.models.sharding_ctx import ShardingCtx

mesh = jax.make_mesh((2, 4), ("data", "model"))
params = moe.init_moe(jax.random.PRNGKey(0), 16, 32, 8)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16), jnp.float32)
ctx = ShardingCtx({}, mesh)
out_sharded = moe.moe_ffn(params, x, k=2, num_experts=8,
                          capacity_factor=8.0, ctx=ctx)
out_local = moe.moe_ffn(params, x, k=2, num_experts=8, capacity_factor=8.0)
np.testing.assert_allclose(np.asarray(out_sharded), np.asarray(out_local),
                           rtol=2e-3, atol=2e-3)
# gradient parity through shard_map
def loss_sharded(p):
    return jnp.sum(moe.moe_ffn(p, x, k=2, num_experts=8,
                               capacity_factor=8.0, ctx=ctx) ** 2)
def loss_local(p):
    return jnp.sum(moe.moe_ffn(p, x, k=2, num_experts=8,
                               capacity_factor=8.0) ** 2)
gs = jax.grad(loss_sharded)(params)
gl = jax.grad(loss_local)(params)
for k2 in ("w_gate", "w_down"):
    np.testing.assert_allclose(np.asarray(gs[k2]), np.asarray(gl[k2]),
                               rtol=5e-3, atol=5e-3)
print("SHARDED-MOE-OK")
"""


def test_shard_map_moe_multi_device():
    r = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                       capture_output=True, text=True, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "SHARDED-MOE-OK" in r.stdout, r.stderr[-2000:]
