"""Optimizer + gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # declared dev dep; CI installs the real one
    from _hypothesis_stub import given, settings, st

from repro.optim import adamw
from repro.optim.compression import (
    compress_grad, dequantize_int8, init_residuals, quantize_int8)
from repro.optim.schedule import cosine_with_warmup


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw.init_state(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    losses = []
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, m = adamw.apply_updates(params, g, state, cfg)
        losses.append(float(loss(params)))
    assert losses[-1] < 1e-2 * losses[0]
    assert m["grad_norm"] > 0


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    state = adamw.init_state(params, cfg)
    huge = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    new_params, _, m = adamw.apply_updates(params, huge, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(1e6)
    assert np.all(np.abs(np.asarray(new_params["w"])) < 10)


def test_state_dtype_bf16():
    params = {"w": jnp.zeros(4)}
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    state = adamw.init_state(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4)}
    _, new_state, _ = adamw.apply_updates(params, g, state, cfg)
    assert new_state["mu"]["w"].dtype == jnp.bfloat16


@given(st.lists(st.floats(min_value=-100, max_value=100,
                          allow_nan=False), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_steps():
    """Sum of transmitted (quantized) grads tracks the sum of true grads."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros(32)
    sent = np.zeros(32)
    true = np.zeros(32)
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=32).astype(np.float32))
        q, scale, residual = compress_grad(g, residual)
        sent += np.asarray(dequantize_int8(q, scale))
        true += np.asarray(g)
    # residual bounds the total divergence
    np.testing.assert_allclose(sent + np.asarray(residual), true,
                               rtol=1e-4, atol=1e-4)


def test_schedule_shape():
    assert float(cosine_with_warmup(0, warmup_steps=10)) == 0.0
    assert float(cosine_with_warmup(10, warmup_steps=10)) == \
        pytest.approx(1.0, abs=0.01)
    end = float(cosine_with_warmup(100000, warmup_steps=10,
                                   total_steps=100000, min_ratio=0.1))
    assert end == pytest.approx(0.1, abs=0.01)


def test_init_residuals_shapes():
    grads = {"a": jnp.ones((2, 3)), "b": {"c": jnp.ones(4)}}
    res = init_residuals(grads)
    assert res["a"].shape == (2, 3)
    assert res["b"]["c"].dtype == jnp.float32
