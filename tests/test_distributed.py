"""Multi-device integration (subprocess, 8 host devices):
elastic checkpoint resharding + int8-compressed data parallelism."""
import subprocess
import sys

import pytest

# Whole-module integration tests: excluded from tier-1 (run nightly / -m slow).
pytestmark = pytest.mark.slow


def _run(script: str) -> str:
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


_ELASTIC = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import Checkpointer

d = tempfile.mkdtemp()
ck = Checkpointer(d)
tree = {"params": {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
ck.save(1, tree)

# "Elastic restart": a different topology loads the same checkpoint.
mesh = jax.make_mesh((2, 4), ("data", "model"))
shardings = {"params": {"w": NamedSharding(mesh, P("data", "model"))}}
out = ck.restore(1, shardings=shardings)
w = out["params"]["w"]
assert len(w.sharding.device_set) == 8
np.testing.assert_array_equal(np.asarray(w), np.asarray(tree["params"]["w"]))
print("ELASTIC-OK")
"""


_COMPRESSED_DP = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim.compression import compressed_psum

mesh = jax.make_mesh((8,), ("data",))
# Data-parallel linear regression with int8-compressed gradient exchange.
rng = np.random.default_rng(0)
X = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
w_true = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
y = X @ w_true

def train(compress, steps=400):
    def shard_fn(Xl, yl):
        def body(_, carry):
            w, residual = carry
            pred = Xl @ w
            g_local = 2.0 * Xl.T @ (pred - yl) / X.shape[0]
            if compress:
                g, new_res = compressed_psum(g_local, residual, "data")
            else:
                g, new_res = jax.lax.psum(g_local, "data"), residual
            return w - 0.05 * g, new_res
        w, _ = jax.lax.fori_loop(
            0, steps, body, (jnp.zeros(16), jnp.zeros(16)))
        return w

    return jax.jit(jax.shard_map(
        shard_fn, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=P(), check_vma=False))(X, y)

w_exact = train(False)
w_comp = train(True)
err_exact = float(jnp.linalg.norm(w_exact - w_true))
err_comp = float(jnp.linalg.norm(w_comp - w_true))
# Error feedback keeps compressed training convergent.
assert err_comp < 0.1, (err_comp, err_exact)
assert abs(err_comp - err_exact) < 0.1
print("COMPRESSED-DP-OK", round(err_exact, 4), round(err_comp, 4))
"""


def test_elastic_restore_new_topology():
    assert "ELASTIC-OK" in _run(_ELASTIC)


def test_compressed_data_parallel_converges():
    assert "COMPRESSED-DP-OK" in _run(_COMPRESSED_DP)
