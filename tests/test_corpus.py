"""Corpus layer: loaders, vendored samples, hermeticity, fit_generator.

The vendored sample set is the hermetic stand-in for DLMC/SuiteSparse;
these tests pin (a) that both file formats round-trip through the real
serializers, (b) that every vendored matrix classifies into its
filename's paper group — including the transposed column-hub fixture
that exposed the row-only classifier bug — and (c) that nothing in the
corpus path can open a network socket unless explicitly opted in.
"""
import socket

import numpy as np
import pytest

from repro.core import patterns
from repro.core.classify import classify
from repro.core.patterns import fit_generator
from repro.data import corpus


@pytest.fixture
def no_network(monkeypatch):
    """Make any socket creation an immediate test failure."""
    def _blocked(*a, **k):
        raise AssertionError("network access attempted in hermetic test")
    monkeypatch.setattr(socket, "socket", _blocked)
    monkeypatch.delenv("REPRO_CORPUS_ALLOW_DOWNLOAD", raising=False)


# --------------------------------------------------------------------- #
# Loaders
# --------------------------------------------------------------------- #

def test_smtx_round_trip(tmp_path):
    m = patterns.erdos_renyi(128, 6, seed=3)
    path = corpus.write_smtx(m, tmp_path / "random__rt.smtx")
    loaded = corpus.load_smtx(path)
    assert loaded.n == m.n and loaded.nnz == m.nnz
    np.testing.assert_array_equal(loaded.rows, m.rows)
    np.testing.assert_array_equal(loaded.cols, m.cols)
    assert loaded.meta["format"] == "smtx"
    # smtx is pattern-only: values are synthesized, not preserved.
    assert np.all(loaded.vals > 0)


def test_mtx_round_trip_with_values(tmp_path):
    m = patterns.banded(96, 3, fill=0.8, seed=4)
    path = corpus.write_mtx(m, tmp_path / "diagonal__rt.mtx")
    loaded = corpus.load_mtx(path)
    np.testing.assert_array_equal(loaded.rows, m.rows)
    np.testing.assert_array_equal(loaded.cols, m.cols)
    np.testing.assert_allclose(loaded.vals, m.vals, rtol=1e-5)


def test_mtx_pattern_field(tmp_path):
    m = patterns.erdos_renyi(64, 4, seed=5)
    path = corpus.write_mtx(m, tmp_path / "random__p.mtx", values=False)
    loaded = corpus.load_mtx(path)
    np.testing.assert_array_equal(loaded.cols, m.cols)
    assert np.all(loaded.vals > 0)


def test_mtx_symmetric_mirrors_off_diagonal(tmp_path):
    path = tmp_path / "sym.mtx"
    path.write_text(
        "%%MatrixMarket matrix coordinate real symmetric\n"
        "% lower triangle only\n"
        "3 3 3\n"
        "1 1 2.0\n"
        "2 1 5.0\n"
        "3 2 7.0\n")
    m = corpus.load_mtx(path)
    dense = np.zeros((3, 3))
    dense[m.rows, m.cols] = m.vals
    np.testing.assert_allclose(dense, dense.T)
    assert m.nnz == 5                       # diagonal not duplicated
    assert dense[0, 0] == 2.0
    assert dense[1, 0] == dense[0, 1] == 5.0
    assert dense[2, 1] == dense[1, 2] == 7.0


def test_smtx_rectangular_square_pads(tmp_path):
    path = tmp_path / "rect.smtx"
    # 2 x 5, nnz=3: rows [0,0,1] cols [0,4,2]
    path.write_text("2, 5, 3\n0 2 3\n0 4 2\n")
    m = corpus.load_smtx(path)
    assert m.n == 5
    assert m.meta["nrows"] == 2 and m.meta["ncols"] == 5
    np.testing.assert_array_equal(m.rows, [0, 0, 1])
    np.testing.assert_array_equal(m.cols, [0, 4, 2])


def test_loader_rejects_malformed(tmp_path):
    bad_ptr = tmp_path / "bad.smtx"
    bad_ptr.write_text("4, 4, 2\n0 1\n0 1\n")       # 2 ptrs, expected 5
    with pytest.raises(ValueError, match="row-pointer"):
        corpus.load_smtx(bad_ptr)
    bad_banner = tmp_path / "bad.mtx"
    bad_banner.write_text("%%MatrixMarket matrix array real general\n1 1\n")
    with pytest.raises(ValueError, match="banner"):
        corpus.load_mtx(bad_banner)
    with pytest.raises(ValueError, match="suffix"):
        corpus.load_matrix(tmp_path / "x.csv")


def test_loader_dedups_and_sorts(tmp_path):
    path = tmp_path / "dup.mtx"
    path.write_text(
        "%%MatrixMarket matrix coordinate real general\n"
        "2 2 3\n2 2 9.0\n1 1 1.0\n1 1 4.0\n")
    m = corpus.load_mtx(path)
    assert m.nnz == 2                       # duplicate (1,1) collapsed
    np.testing.assert_array_equal(m.rows, [0, 1])
    assert m.vals[0] == 1.0                 # first value wins


# --------------------------------------------------------------------- #
# Vendored corpus (hermetic)
# --------------------------------------------------------------------- #

def test_vendored_set_covers_all_groups(no_network):
    entries = corpus.vendored_entries()
    assert len(entries) >= 8
    assert {e.group for e in entries} == set(corpus.GROUPS)
    assert {e.path.suffix for e in entries} == {".smtx", ".mtx"}


@pytest.mark.parametrize(
    "entry", corpus.vendored_entries(),
    ids=lambda e: f"{e.group}__{e.name}")
def test_vendored_matrix_classifies_into_its_group(entry, no_network):
    """Golden regime labels — includes the transposed column-hub fixture
    (``scale_free__colhub_192``) that pins the row-only classifier bug."""
    m = entry.load()
    report = classify(m)
    assert report.regime == entry.group, report.stats
    assert m.meta["group"] == entry.group


def test_colhub_fixture_detects_column_axis(no_network):
    entry = next(e for e in corpus.vendored_entries()
                 if e.name == "colhub_192")
    report = classify(entry.load())
    assert report.regime == "scale_free"
    assert report.stats["tail_axis"] == "col"
    assert report.stats["col_gini"] > report.stats["row_gini"]


def test_corpus_entries_precedence(tmp_path, monkeypatch):
    m = patterns.erdos_renyi(32, 2, seed=0)
    corpus.write_smtx(m, tmp_path / "random__only.smtx")
    monkeypatch.setenv("REPRO_CORPUS_DIR", str(tmp_path))
    entries = corpus.corpus_entries()
    assert [e.name for e in entries] == ["only"]
    # Explicit root beats the environment.
    other = tmp_path / "other"
    other.mkdir()
    assert corpus.corpus_entries(other) == ()
    monkeypatch.delenv("REPRO_CORPUS_DIR")
    assert len(corpus.corpus_entries()) >= 8       # vendored fallback


def test_scan_rejects_unknown_group(tmp_path):
    (tmp_path / "bogus__x.smtx").write_text("1, 1, 0\n0 0\n\n")
    with pytest.raises(ValueError, match="bogus"):
        corpus.corpus_entries(tmp_path)


def test_load_corpus_group_filter(no_network):
    mats = corpus.load_corpus(groups=["diagonal"])
    assert mats and all(m.meta["group"] == "diagonal"
                        for m in mats.values())


# --------------------------------------------------------------------- #
# Downloader opt-in
# --------------------------------------------------------------------- #

def test_download_refuses_without_opt_in(tmp_path, no_network):
    with pytest.raises(corpus.CorpusDownloadDisabled,
                       match="hermetic by default"):
        corpus.download("https://example.com/m.mtx", tmp_path / "m.mtx")


def test_download_returns_existing_file_without_network(tmp_path,
                                                        no_network):
    dest = tmp_path / "have.mtx"
    dest.write_text("cached")
    # No opt-in, sockets blocked: the cached file short-circuits both.
    assert corpus.download("https://example.com/x", dest) == dest


def test_download_opt_in_fetches_file_url(tmp_path):
    src = tmp_path / "src.smtx"
    src.write_text("1, 1, 0\n0 0\n\n")
    dest = tmp_path / "fetched.smtx"
    out = corpus.download(src.as_uri(), dest, allow=True)
    assert out == dest and dest.read_text() == src.read_text()
    assert not dest.with_suffix(".smtx.part").exists()


# --------------------------------------------------------------------- #
# fit_generator: corpus -> synthetic bridge
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("gen", [
    lambda: patterns.erdos_renyi(256, 8, seed=1),
    lambda: patterns.banded(256, 2, fill=1.0, seed=4),
    lambda: patterns.blocked(256, t=32, num_blocks=16, nnz_per_block=256,
                             seed=6),
    lambda: patterns.scale_free(256, 8, alpha=2.1, seed=8),
])
def test_fit_generator_preserves_regime(gen):
    src = gen()
    report = classify(src)
    fitted = fit_generator(report, seed=2)
    assert fitted.meta["fitted_from"]["regime"] == report.regime
    assert classify(fitted).regime == report.regime
    # Density within 2x of the source (structural, not exact).
    assert fitted.nnz == pytest.approx(src.nnz, rel=1.0)


def test_fit_generator_scales_size(no_network):
    entry = next(e for e in corpus.vendored_entries()
                 if e.group == "blocked")
    report = classify(entry.load())
    big = fit_generator(report, n=1024, seed=3)
    assert big.n == 1024
    assert classify(big).regime == "blocked"
