"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels, sparse
from repro.core import banded as gen_banded
from repro.core import blocked as gen_blocked
from repro.core import erdos_renyi
from repro.kernels import ref

# This module deliberately exercises the deprecated container-level
# wrappers in repro.kernels.ops (they expose packing knobs — row_tile,
# chunk, b_tile, block_d — the registry derives itself); the registry
# path is covered by test_registry / test_differential.  Silence the
# DeprecationWarning they now raise, except in the explicit test below.
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

RNG = np.random.default_rng(0)


def _b(n, d, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=(n, d))).astype(dtype)


@pytest.mark.parametrize("t", [16, 32])
@pytest.mark.parametrize("d,block_d", [(16, 16), (64, 32), (128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bcsr_kernel_sweep(t, d, block_d, dtype):
    n = 8 * t
    m = gen_blocked(n, t=t, num_blocks=20, nnz_per_block=3 * t, seed=t + d)
    a = sparse.coo_to_bcsr(m, t, dtype=jnp.float32)
    b = _b(n, d, dtype)
    out = kernels.bcsr_spmm(a, b, block_d=block_d)
    expect = ref.bcsr_ref(np.asarray(a.blocks), a.block_rows, a.block_cols,
                          b, n=n, t=t)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_bcsr_kernel_empty_rows_padded():
    """Block rows with no nonzero blocks must still produce zero C tiles."""
    t, n = 16, 128
    m = gen_blocked(n, t=t, num_blocks=2, nnz_per_block=20, seed=3)
    a = sparse.coo_to_bcsr(m, t)
    b = _b(n, 8)
    out = kernels.bcsr_spmm(a, b, block_d=8)
    expect = ref.bcsr_ref(np.asarray(a.blocks), a.block_rows, a.block_cols,
                          b, n=n, t=t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("pattern", ["er", "banded", "blocked", "powerlaw"])
@pytest.mark.parametrize("d,block_d", [(8, 8), (64, 32)])
def test_csr_kernel_sweep(pattern, d, block_d):
    from repro.core import scale_free
    n = 256
    gen = {
        "er": lambda: erdos_renyi(n, 6, seed=1),
        "banded": lambda: gen_banded(n, 3, seed=2),
        "blocked": lambda: gen_blocked(n, t=16, num_blocks=32,
                                       nnz_per_block=12, seed=3),
        "powerlaw": lambda: scale_free(n, 8, seed=4),
    }[pattern]
    m = gen()
    a = sparse.coo_to_csr(m)
    b = _b(n, d)
    out = kernels.csr_spmm(a, b, row_tile=8, chunk=32, block_d=block_d)
    expect = sparse.coo_to_dense(m) @ b
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("b_tile", [32, 64, 100])
def test_csr_kernel_streamed_b_matches_ref(b_tile):
    """Slab-streamed layouts (incl. n % b_tile != 0) match the oracle."""
    from repro.kernels import ref
    n = 256
    m = erdos_renyi(n, 6, seed=7)
    a = sparse.coo_to_csr(m)
    b = _b(n, 64)
    out = kernels.csr_spmm(a, b, row_tile=8, chunk=32, block_d=32,
                           b_tile=b_tile)
    expect = ref.csr_ref(a.indptr, a.indices, a.data, b, n=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


def test_csr_kernel_streams_past_vmem():
    """The acceptance case: n * bd * 4 exceeds the (shrunk) VMEM budget,
    so whole-B residency is impossible; the dispatcher's pallas path must
    pick a multi-slab layout and still match the oracle."""
    import dataclasses
    from repro.core.hardware import TPU_V5E
    from repro.kernels import ref, registry

    n, d = 512, 64
    vmem = 96 * 1024
    assert n * d * 4 > vmem                  # old bound violated
    hw = dataclasses.replace(TPU_V5E, vmem_bytes=vmem)
    m = erdos_renyi(n, 8, seed=9)
    disp = sparse.Dispatcher(hardware=hw, backend="pallas",
                             calibration=False)
    plan = disp.plan(m, d, strategy="csr")
    run = disp.executor(m, plan)
    # The cached layout must actually be multi-slab streamed.
    layout = next(v for k, v in disp._converted.items() if k[1] == "layout")
    assert layout["b_tile"] is not None and layout["b_tile"] < n
    assert int(np.asarray(layout["arrays"][1]).max()) > 0   # >1 slab used
    spec = registry.get("csr", "pallas")
    ctx = registry.KernelContext(hardware=hw)
    assert spec.vmem_footprint(n, d, ctx) <= vmem
    a = sparse.coo_to_csr(m)
    b = _b(n, d)
    expect = ref.csr_ref(a.indptr, a.indices, a.data, b, n=n)
    np.testing.assert_allclose(np.asarray(run(b)), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("pattern", ["er", "powerlaw"])
@pytest.mark.parametrize("b_tile", [None, 8, 100])
def test_binned_kernel_sweep(pattern, b_tile):
    """Slab-binned kernel across slab sizings, including b_tile=8 (the
    degenerate one-row-tile slab: maximum binning overhead) and a
    non-multiple-of-8 slab edge."""
    from repro.core import scale_free
    n = 256
    m = (erdos_renyi(n, 6, seed=11) if pattern == "er"
         else scale_free(n, 8, alpha=2.05, seed=12))
    a = sparse.coo_to_csr(m)
    b = _b(n, 64)
    out = kernels.binned_spmm(a, b, chunk=32, block_d=32, b_tile=b_tile)
    expect = ref.csr_ref(a.indptr, a.indices, a.data, b, n=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


def test_binned_kernel_streams_past_vmem():
    """Mirror of the CSR acceptance case for the binned tier: with VMEM
    shrunk below whole-B residency the dispatcher's pallas path must bin
    into multiple B slabs and still match the oracle."""
    import dataclasses
    from repro.core.hardware import TPU_V5E
    from repro.kernels import registry

    n, d = 512, 64
    vmem = 96 * 1024
    hw = dataclasses.replace(TPU_V5E, vmem_bytes=vmem)
    m = erdos_renyi(n, 8, seed=13)
    disp = sparse.Dispatcher(hardware=hw, backend="pallas",
                             calibration=False)
    plan = disp.plan(m, d, strategy="binned")
    run = disp.executor(m, plan)
    layout = next(v for k, v in disp._converted.items()
                  if k[1] == "layout")
    assert layout["b_tile"] is not None and layout["b_tile"] < n
    # chunk_slabs is arrays[2]: >0 means the binning touched >1 B slab.
    assert int(np.asarray(layout["arrays"][2]).max()) > 0
    spec = registry.get("binned", "pallas")
    ctx = registry.KernelContext(hardware=hw)
    assert spec.vmem_footprint(n, d, ctx) <= vmem
    a = sparse.coo_to_csr(m)
    b = _b(n, d)
    expect = ref.csr_ref(a.indptr, a.indices, a.data, b, n=n)
    np.testing.assert_allclose(np.asarray(run(b)), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


def test_binned_kernel_degenerate_bins():
    """Degenerate slab occupancies: all nonzeros in one slab (every
    other bin empty) and the all-zero matrix (one synthetic zero visit)."""
    from repro.core.patterns import COOMatrix
    n = 64
    # Hub column block: every nonzero lands in B rows [0, 8) — with
    # b_tile=8 exactly one of eight slabs is ever visited.
    rng = np.random.default_rng(5)
    rows = np.arange(n, dtype=np.int32)
    cols = rng.integers(0, 8, size=n).astype(np.int32)
    m = COOMatrix(n=n, rows=rows, cols=cols,
                  vals=np.ones(n, np.float32), pattern="hub_cols")
    a = sparse.coo_to_csr(m)
    b = _b(n, 16)
    out = kernels.binned_spmm(a, b, chunk=32, block_d=16, b_tile=8)
    expect = ref.csr_ref(a.indptr, a.indices, a.data, b, n=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)

    empty = COOMatrix(n=n, rows=np.zeros(0, np.int32),
                      cols=np.zeros(0, np.int32),
                      vals=np.zeros(0, np.float32), pattern="empty")
    ae = sparse.coo_to_csr(empty)
    oute = kernels.binned_spmm(ae, b, chunk=32, block_d=16, b_tile=8)
    assert not np.any(np.asarray(oute))
    outr = kernels.rowsplit_spmm(ae, b, chunk=32, block_d=16)
    assert not np.any(np.asarray(outr))


@pytest.mark.parametrize("chunk", [32, 128])
def test_rowsplit_kernel_skewed_rows(chunk):
    """Load-balance stress: one hub row with n nonzeros next to
    singleton rows — chunks must cross row boundaries correctly, and the
    epilogue must scatter windowed partials to the right rows."""
    from repro.core.patterns import COOMatrix
    n = 128
    rows = np.concatenate([np.full(n, 3), np.arange(n)]).astype(np.int32)
    cols = np.concatenate([np.arange(n), np.arange(n)]).astype(np.int32)
    vals = (1.0 + np.arange(2 * n)).astype(np.float32) / n
    m = COOMatrix(n=n, rows=rows, cols=cols, vals=vals, pattern="skew")
    a = sparse.coo_to_csr(m)
    b = _b(n, 32)
    out = kernels.rowsplit_spmm(a, b, chunk=chunk, block_d=32)
    expect = ref.csr_ref(a.indptr, a.indices, a.data, b, n=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


def test_csr_kernel_empty_and_ragged_rows():
    """Empty rows still get zeroed C tiles; rows crossing chunk boundaries
    accumulate across grid steps."""
    n = 64
    rows = np.array([0] * 50 + [63] * 3)       # row 0 spans >1 chunk of 32
    cols = np.arange(53) % n
    from repro.core import COOMatrix
    m_coo = COOMatrix(
        n=n, rows=rows.astype(np.int32), cols=cols.astype(np.int32),
        vals=np.ones(53), pattern="custom")
    a = sparse.coo_to_csr(m_coo)
    b = _b(n, 8)
    out = kernels.csr_spmm(a, b, row_tile=8, chunk=32, block_d=8)
    expect = sparse.coo_to_dense(m_coo) @ b
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("bandwidth", [1, 5, 17])
@pytest.mark.parametrize("d", [16, 64])
def test_banded_kernel_sweep(bandwidth, d):
    n, t = 256, 32
    m = gen_banded(n, bandwidth, fill=0.9, seed=bandwidth)
    dia = sparse.coo_to_dia(m)
    band, w = kernels.band_to_blocks(np.asarray(dia.data), dia.offsets,
                                     n=n, t=t)
    b = _b(n, d)
    out = kernels.banded_spmm(band, b, t=t, w=w, block_d=d)
    expect = ref.banded_ref(np.asarray(band), b, t=t, w=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("E,bm", [(4, 64), (8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(E, bm, dtype):
    T, K, N = 4 * bm, 128, 256
    x = _b(T, K, dtype)
    w = jnp.asarray(RNG.normal(size=(E, K, N))).astype(dtype)
    gids = jnp.asarray(RNG.integers(0, E, size=T // bm).astype(np.int32))
    out = kernels.grouped_matmul(x, w, gids, bm=bm, bk=64, bn=128)
    expect = ref.grouped_matmul_ref(x, w, gids, bm=bm)
    tol = 1e-1 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_grouped_matmul_matches_moe_semantics():
    """grouped_matmul on expert-sorted tokens == per-expert dense matmul."""
    E, bm, K, N = 4, 32, 64, 64
    gids = jnp.asarray([0, 1, 1, 3], jnp.int32)
    x = _b(4 * bm, K)
    w = jnp.asarray(RNG.normal(size=(E, K, N)).astype(np.float32))
    out = kernels.grouped_matmul(x, w, gids, bm=bm, bk=64, bn=64)
    for blk in range(4):
        seg = slice(blk * bm, (blk + 1) * bm)
        np.testing.assert_allclose(
            np.asarray(out[seg]),
            np.asarray(x[seg] @ w[int(gids[blk])]), rtol=2e-3, atol=2e-3)


def test_kernel_rooflines():
    m = gen_blocked(256, t=32, num_blocks=30, nnz_per_block=64, seed=1)
    a = sparse.coo_to_bcsr(m, 32)
    r = kernels.bcsr_kernel_roofline(a, 64)
    assert 0 < r.mxu_utilization <= 1
    assert r.useful_flops <= r.mxu_flops
    assert r.attainable_flops_per_s > 0
    c = kernels.csr_kernel_roofline(sparse.coo_to_csr(m), 64)
    assert c.mxu_utilization == 1.0   # CSR issues only useful FLOPs
    assert c.useful_flops == pytest.approx(r.useful_flops)
    assert c.ai < r.ai                # random-gather traffic dominates CSR
    g = kernels.grouped_matmul_roofline(4096, 4096, 1536, 128)
    assert g.mxu_utilization == 1.0   # block-diagonal: every block dense
    assert g.ai > r.ai                # MoE blocks beat generic sparse blocks


def test_ops_wrappers_raise_deprecation_warning():
    """The container-level wrappers warn callers toward the registry."""
    m = gen_blocked(64, t=16, num_blocks=4, nnz_per_block=20, seed=9)
    a = sparse.coo_to_bcsr(m, 16)
    b = _b(64, 8)
    with pytest.warns(DeprecationWarning, match="registry"):
        kernels.bcsr_spmm(a, b, block_d=8)
    with pytest.warns(DeprecationWarning, match="registry"):
        kernels.csr_spmm(sparse.coo_to_csr(m), b, chunk=32, block_d=8)
