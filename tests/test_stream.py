"""Streamed dispatch (repro.sparse.stream): plan once, execute many."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.core import banded, blocked, erdos_renyi, scale_free
from repro.core.hardware import HOST_CPU

N = 512


def _mats():
    return {
        "uniform": erdos_renyi(N, 8, seed=1),
        "banded": banded(N, 3, fill=0.9, seed=2),
        "block": blocked(N, t=32, num_blocks=N // 16, nnz_per_block=320,
                         seed=3),
        "scale_free": scale_free(N, 8, alpha=2.2, seed=4),
    }


def _b(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


# --------------------------------------------------------------------- #
# Numerics: streamed execution must match per-call dispatch exactly.
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("pattern", sorted(_mats()))
def test_execute_many_matches_per_call_spmm(pattern):
    """Acceptance: plan(m, spec).execute_many(bs) == per-call spmm(m, b)."""
    m = _mats()[pattern]
    bs = [_b(N, 8, seed=s) for s in range(4)]
    plan = sparse.plan(m, sparse.BSpec(d=8, reuse=len(bs)))
    outs = plan.execute_many(bs)
    assert outs.shape == (len(bs), N, 8)
    for i, b in enumerate(bs):
        ref = sparse.spmm(m, b)
        np.testing.assert_allclose(np.asarray(outs[i]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    dense = np.asarray(sparse.coo_to_dense(m))
    np.testing.assert_allclose(np.asarray(outs[0]),
                               dense @ np.asarray(bs[0]),
                               rtol=5e-4, atol=5e-4)


def test_execute_many_accepts_stacked_array_and_empty():
    m = _mats()["uniform"]
    stacked = jnp.stack([_b(N, 4, seed=s) for s in range(3)])
    plan = sparse.plan(m, 4, reuse=3)
    outs = plan.execute_many(stacked)
    assert outs.shape == (3, N, 4)
    empty = plan.execute_many([])
    assert empty.shape == (0, N, 4)


def test_execute_wide_shards_columns():
    """One wide B sharded into planned-width column blocks (+ remainder)."""
    m = _mats()["block"]
    plan = sparse.plan(m, sparse.BSpec(d=8, reuse=16))
    wide = _b(N, 20, seed=9)          # 8 + 8 + 4: remainder block included
    out = plan.execute_wide(wide)
    ref = np.asarray(sparse.coo_to_dense(m)) @ np.asarray(wide)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=5e-4, atol=5e-4)


def test_pallas_backend_stream_matches_dense():
    disp = sparse.Dispatcher(backend="pallas", bcsr_block=32)
    m = _mats()["block"]
    plan = sparse.plan(m, 16, reuse=4, dispatcher=disp)
    b = _b(N, 16, seed=5)
    ref = np.asarray(sparse.coo_to_dense(m)) @ np.asarray(b)
    np.testing.assert_allclose(np.asarray(plan.execute(b)), ref,
                               rtol=5e-4, atol=5e-4)


# --------------------------------------------------------------------- #
# The reuse horizon in the cost model.
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("pattern", sorted(_mats()))
def test_reuse_monotonicity(pattern):
    """A higher expected reuse never picks a format with a worse amortized
    prediction: the chosen candidate's amortized GFLOP/s is nondecreasing
    in the reuse horizon (argmax of per-format curves that each increase
    with reuse)."""
    m = _mats()[pattern]
    prev = -1.0
    for r in (1, 2, 4, 8, 32, 256, 4096):
        plan = sparse.plan_spmm(m, 16, reuse=r)
        amort = plan.candidate(plan.chosen).amortized_gflops
        assert amort >= prev - 1e-12, (r, amort, prev)
        prev = amort


def test_reuse_horizon_can_flip_the_chosen_format():
    """The streaming layer's reason to exist: fed a short horizon the
    dispatcher picks the cheap-to-build format, fed a long one the
    expensive-but-faster format (conversion amortization, Section III)."""
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=10e9)
    m = blocked(N, t=64, num_blocks=8, nnz_per_block=320, seed=11)
    # Compute-bound ceilings tuned so BCSR's steady state narrowly beats
    # CSR while its dense-block conversion is ~4x CSR's: the flip point
    # lands between reuse=1 and reuse=8.
    disp = sparse.Dispatcher(
        hardware=hw, backend="jax",
        efficiency={"csr": (0.02, 0.0), "bcsr": (0.30, 0.0),
                    "ell": (0.001, 0.0), "dia": (0.001, 0.0),
                    "binned": (0.001, 0.0), "rowsplit": (0.001, 0.0),
                    "ell_coo": (0.001, 0.0)})
    short = sparse.plan(m, sparse.BSpec(d=16, reuse=1), dispatcher=disp)
    long = sparse.plan(m, sparse.BSpec(d=16, reuse=10_000), dispatcher=disp)
    assert short.chosen == "csr"
    assert long.chosen == "bcsr"
    # Both still compute the same thing.
    b = _b(N, 16, seed=3)
    np.testing.assert_allclose(np.asarray(short.execute(b)),
                               np.asarray(long.execute(b)),
                               rtol=5e-4, atol=5e-4)


def test_reuse_drift_warns_and_suggests_replan(caplog):
    """Executing >2x past the planned horizon logs one warning and flips
    stats()["replan_suggested"] (ROADMAP streamed-dispatch follow-up)."""
    import logging
    m = _mats()["uniform"]
    plan = sparse.plan(m, 4, reuse=2)
    bs = [_b(N, 4, seed=s) for s in range(5)]       # 5 > 2 * 2
    with caplog.at_level(logging.WARNING, logger="repro.sparse.stream"):
        plan.execute_many(bs)
    msgs = [r.message for r in caplog.records
            if "reuse horizon" in r.message]
    assert len(msgs) == 1
    assert "replan" in msgs[0]
    assert plan.stats()["replan_suggested"] is True
    # Warned once: replaying more batches stays quiet.
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.sparse.stream"):
        plan.execute_many(bs[:2])
    assert not [r for r in caplog.records if "reuse horizon" in r.message]


def test_reuse_drift_warns_on_per_request_execute(caplog):
    """The serving entry point calls execute() per request (serve.py);
    drifting past the horizon there must warn too, not just in
    execute_many."""
    import logging
    m = _mats()["uniform"]
    plan = sparse.plan(m, 4, reuse=2)
    b = _b(N, 4)
    with caplog.at_level(logging.WARNING, logger="repro.sparse.stream"):
        for _ in range(5):                       # 5 > 2 * 2
            plan.execute(b)
    assert len([r for r in caplog.records
                if "reuse horizon" in r.message]) == 1
    assert plan.stats()["replan_suggested"] is True


def test_within_horizon_stream_does_not_warn(caplog):
    import logging
    m = _mats()["banded"]
    plan = sparse.plan(m, 4, reuse=8)
    with caplog.at_level(logging.WARNING, logger="repro.sparse.stream"):
        plan.execute_many([_b(N, 4, seed=s) for s in range(4)])
    assert not [r for r in caplog.records if "reuse horizon" in r.message]
    assert plan.stats()["replan_suggested"] is False


def test_replan_at_observed_horizon_can_flip_format():
    """replan(observed) rebuilds the plan with the conversion model fed
    the realized horizon — the format flips exactly like planning fresh."""
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=10e9)
    m = blocked(N, t=64, num_blocks=8, nnz_per_block=320, seed=11)
    disp = sparse.Dispatcher(
        hardware=hw, backend="jax", calibration=False,
        efficiency={"csr": (0.02, 0.0), "bcsr": (0.30, 0.0),
                    "ell": (0.001, 0.0), "dia": (0.001, 0.0),
                    "binned": (0.001, 0.0), "rowsplit": (0.001, 0.0),
                    "ell_coo": (0.001, 0.0)})
    plan = sparse.plan(m, sparse.BSpec(d=16, reuse=1), dispatcher=disp)
    assert plan.chosen == "csr"
    replanned = plan.replan(10_000)
    assert replanned.chosen == "bcsr"
    assert replanned.spec.reuse == 10_000
    assert replanned.spec.d == plan.spec.d
    b = _b(N, 16, seed=3)
    np.testing.assert_allclose(np.asarray(plan.execute(b)),
                               np.asarray(replanned.execute(b)),
                               rtol=5e-4, atol=5e-4)
    with pytest.raises(ValueError):
        plan.replan(0)


def test_spec_coercion_and_stats():
    m = _mats()["banded"]
    p1 = sparse.plan(m, 8)                       # int width
    assert p1.spec == sparse.BSpec(d=8, reuse=32)
    p2 = sparse.plan(m, _b(N, 8), reuse=7)       # example batch
    assert p2.spec.d == 8 and p2.spec.reuse == 7
    p2.execute(_b(N, 8))
    s = p2.stats()
    assert s["planned_reuse"] == 7 and s["executed"] == 1
    assert s["chosen"] == p2.chosen
    p2.reset_stats()                             # warm-up discount path
    assert p2.stats()["executed"] == 0
    assert sparse.as_b_spec(sparse.BSpec(d=4), reuse=9).reuse == 9


def test_execute_wide_zero_columns():
    m = _mats()["banded"]
    plan = sparse.plan(m, 8, reuse=4)
    out = plan.execute_wide(jnp.zeros((N, 0), jnp.float32))
    assert out.shape == (N, 0)
    assert plan.stats()["executed"] == 0


def test_stream_plan_bad_inputs_raise():
    m = _mats()["uniform"]
    plan = sparse.plan(m, 8, reuse=4)
    with pytest.raises(ValueError):
        plan.execute(_b(N, 16))                  # wrong width
    with pytest.raises(ValueError):
        plan.execute(_b(N + 2, 8))               # wrong row count
    with pytest.raises(ValueError):
        plan.execute_wide(_b(N, 16), block_d=0)
    with pytest.raises(ValueError):
        sparse.BSpec(d=0)
    with pytest.raises(ValueError):
        sparse.BSpec(d=4, reuse=0)
    with pytest.raises(TypeError):
        sparse.as_b_spec("csr")
    with pytest.raises(ValueError):
        sparse.plan(m, 8, strategy="nope")


def test_serve_spmm_stream_path(capsys):
    """The launch-layer serving integration (serve.py --spmm-stream)."""
    import argparse
    from repro.launch.serve import build_stream_matrix, serve_spmm_stream

    m = build_stream_matrix("moe-block", 256)
    # Block-diagonal expert dispatch: every nonzero inside a diagonal block.
    assert m.n == 256 and m.nnz == 256 * 64
    assert (m.rows // 64 == m.cols // 64).all()
    for structure in ("banded", "scale-free", "uniform"):
        assert build_stream_matrix(structure, 256).nnz > 0
    with pytest.raises(ValueError):
        build_stream_matrix("nope", 256)
    with pytest.raises(ValueError):
        build_stream_matrix("moe-block", 100)     # not a multiple of t

    args = argparse.Namespace(spmm_structure="moe-block", spmm_n=256,
                              spmm_d=8, spmm_steps=2, spmm_compare=True)
    serve_spmm_stream(args)
    out = capsys.readouterr().out
    assert "planned for reuse=2" in out
    assert "steady-state" in out
    assert "per-call dispatch" in out
    assert "'executed': 2" in out                 # warm-up discounted


def test_stream_uses_shared_default_dispatcher_caches():
    """sparse.plan with no dispatcher reuses the module-level caches, so a
    following sparse.spmm hits the same plan/conversion entries."""
    m = erdos_renyi(N, 4, seed=42)
    disp = sparse.default_dispatcher()
    plan = sparse.plan(m, 8, reuse=32)
    cached = disp.plan(m, 8)
    assert cached is plan.dispatch
