"""Trainer integration: loss descent, checkpoint/restart, watchdog."""
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig
from repro.train.trainer import Trainer, TrainerConfig

import pytest
# Whole-module integration tests: excluded from tier-1 (run nightly / -m slow).
pytestmark = pytest.mark.slow

SMALL_SHAPE = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")


def _trainer(tmp_path, ckpt_every=4):
    cfg = get_config("llama3.2-1b").reduced()
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                         schedule_kwargs={"warmup_steps": 2,
                                          "total_steps": 1000})
    return Trainer(cfg, SMALL_SHAPE, tcfg,
                   data_cfg=DataConfig(seed=1))


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path)
    tr.run(10)
    first = np.mean([h["loss"] for h in tr.history[:3]])
    last = np.mean([h["loss"] for h in tr.history[-3:]])
    assert last < first


def test_restart_resumes_without_replay(tmp_path):
    tr1 = _trainer(tmp_path)
    tr1.run(12, stop_after=8)          # "preemption" after step 7 (ckpt@7)
    assert tr1.ckpt.latest_step() == 7
    tr2 = _trainer(tmp_path)
    tr2.init_or_restore()
    assert tr2.start_step == 8
    tr2.run(12)
    steps = [h["step"] for h in tr2.history]
    assert steps == list(range(8, 12))


def test_restart_equivalence(tmp_path):
    """Interrupted-and-resumed training equals uninterrupted training."""
    tr_full = _trainer(tmp_path / "a", ckpt_every=100)
    tr_full.run(8)
    w_full = np.asarray(tr_full.params["final_norm"]["scale"])

    tr1 = _trainer(tmp_path / "b", ckpt_every=4)
    tr1.run(8, stop_after=4)           # stops after step 3 (ckpt at 3)
    tr2 = _trainer(tmp_path / "b", ckpt_every=4)
    tr2.run(8)
    w_resumed = np.asarray(tr2.params["final_norm"]["scale"])
    np.testing.assert_allclose(w_full, w_resumed, rtol=1e-4, atol=1e-5)


def test_straggler_watchdog():
    tr = _trainer.__wrapped__ if hasattr(_trainer, "__wrapped__") else None
    cfg = get_config("llama3.2-1b").reduced()
    tcfg = TrainerConfig(ckpt_dir="/tmp/unused_watchdog",
                         straggler_factor=2.0, ema_decay=0.5)
    t = Trainer(cfg, SMALL_SHAPE, tcfg)
    t._watchdog(0, 1.0)
    t._watchdog(1, 1.1)
    assert not t.straggler_events
    t._watchdog(2, 5.0)                # 5x EMA -> straggler
    assert len(t.straggler_events) == 1
    assert t.straggler_events[0][0] == 2
