"""Structure-aware dispatch: planning, policy, caching, and execution."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.core import banded, blocked, erdos_renyi, scale_free

N = 512


def _mats():
    return {
        "random": erdos_renyi(N, 8, seed=1),
        "banded": banded(N, 3, fill=0.9, seed=2),
        "fem": blocked(N, t=32, num_blocks=N // 16, nnz_per_block=320,
                       seed=3),
        "powerlaw": scale_free(N, 8, alpha=2.2, seed=4),
    }


def _b(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


# --------------------------------------------------------------------- #
# Numerics: every strategy x pattern must agree with the dense reference.
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("pattern", sorted(_mats()))
@pytest.mark.parametrize("strategy", ["auto", "csr"])
def test_spmm_matches_dense(pattern, strategy):
    m = _mats()[pattern]
    b = _b(N, 8)
    ref = sparse.coo_to_dense(m) @ b
    out = sparse.spmm(m, b, strategy=strategy)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_forced_strategies_match_dense():
    m = _mats()["banded"]
    b = _b(N, 4)
    ref = sparse.coo_to_dense(m) @ b
    for strategy in ("ell", "bcsr", "dia"):
        out = sparse.spmm(m, b, strategy=strategy)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-4, atol=5e-4, err_msg=strategy)


def test_pallas_backend_matches_dense():
    disp = sparse.Dispatcher(backend="pallas", bcsr_block=32)
    b = _b(N, 16)
    for pattern, m in _mats().items():
        ref = sparse.coo_to_dense(m) @ b
        out = disp.spmm(m, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-4, atol=5e-4, err_msg=pattern)


# --------------------------------------------------------------------- #
# Policy: the paper's structure -> format mapping, with skip reasons.
# --------------------------------------------------------------------- #

#: Formats sharing the CSR gather/segment-sum algebra: the acceptable
#: picks for hub/scale-free structure (plain ELL must still policy-skip).
GATHER_FAMILY = {"csr", "binned", "rowsplit", "ell_coo"}


def test_expected_formats_per_structure():
    """The acceptance mapping: banded->dia, dense blocks->bcsr,
    hub/scale-free->the CSR gather family (ELL policy-skipped there)."""
    mats = _mats()
    d = 64
    assert sparse.plan_spmm(mats["banded"], d).chosen == "dia"
    assert sparse.plan_spmm(mats["fem"], d).chosen == "bcsr"
    plan = sparse.plan_spmm(mats["powerlaw"], d)
    assert plan.chosen in GATHER_FAMILY
    assert "ell" in plan.skips
    assert "padding" in plan.skips["ell"]


def test_binned_model_wins_high_skew_on_bandwidth_bound_hw():
    """The model-level form of PR 8's scale-free claim, deterministic:
    on a bandwidth-bound part (TPU v5e) the slab-binned traversal's
    collapsed B-traffic term must rank binned above plain CSR for
    high-skew scale-free structure once B outgrows on-chip residency.
    (The measured form is soft-reported by benchmarks/run.py.)"""
    from repro.core.hardware import TPU_V5E
    m = scale_free(8192, 16, alpha=2.05, seed=10)
    disp = sparse.Dispatcher(hardware=TPU_V5E, backend="pallas",
                             calibration=False)
    plan = disp.plan(m, 64)
    assert plan.regime == "scale_free"
    binned = plan.candidate("binned")
    csr = plan.candidate("csr")
    assert binned.eligible and csr.eligible
    assert binned.predicted_gflops > csr.predicted_gflops
    assert plan.chosen in GATHER_FAMILY


@pytest.mark.parametrize("structure,d", [("uniform", 8), ("uniform", 64),
                                         ("scale_free", 8),
                                         ("scale_free", 64)])
def test_reduced_precision_roofline_gain_on_bandwidth_bound_hw(structure, d):
    """The tentpole's model-level claim, deterministic: on a
    bandwidth-bound part (TPU v5e) the roofline must predict >= 1.5x
    attainable GFLOP/s for bf16 values + int16 indices over fp32 + int32
    on the CSR-family kernels for bandwidth-bound structures (uniform /
    scale-free at d >= 8) — halving the bytes-per-nonzero on a
    memory-bound kernel halves its time bound.  (The measured form is
    soft-reported by benchmarks/run.py's bf16 smoke lane.)"""
    from repro.core.hardware import TPU_V5E
    if structure == "uniform":
        m = erdos_renyi(8192, 16, seed=11)
    else:
        m = scale_free(8192, 16, alpha=2.05, seed=11)
    disp = sparse.Dispatcher(hardware=TPU_V5E, backend="pallas",
                             calibration=False)
    # tolerance admits bf16 (eps 2^-7) so the reduced rows rank eligibly.
    plan = disp.plan(m, d, tolerance=1e-2)
    gained = []
    for name in ("csr", "binned", "rowsplit", "ell_coo"):
        lo = plan.candidate(name, "bf16i16")
        hi = plan.candidate(name, "f32i32")
        if not (lo.eligible and hi.eligible):
            continue                  # structure-gated format: not at issue
        # Halved bytes-per-nonzero must exactly double the modeled AI.
        assert lo.ai == pytest.approx(2.0 * hi.ai, rel=1e-6)
        # The >= 1.5x attainable claim holds wherever the bf16 row is
        # still under the memory roof; rows the compact layout promotes
        # all the way into the compute-bound regime are the win itself,
        # not an exception (their gain is capped by the ceiling).
        ceiling_capped = (lo.predicted_gflops
                          < TPU_V5E.attainable(lo.ai) / 1e9 * 0.999)
        if not ceiling_capped:
            assert lo.predicted_gflops >= 1.5 * hi.predicted_gflops, (
                f"{name} @ d={d} ({structure}): bf16i16 predicts "
                f"{lo.predicted_gflops:.1f} GF/s vs f32i32 "
                f"{hi.predicted_gflops:.1f} GF/s")
            gained.append(name)
    # Non-vacuity: every swept config keeps >= 1 CSR-family format under
    # the memory roof at bf16i16 with the full >= 1.5x predicted gain.
    assert gained, f"no bandwidth-bound CSR-family row at d={d}"
    # The winning plan itself runs reduced under this tolerance.
    assert plan.precision in ("bf16i16", "bf16i32")


def test_skip_reasons_recorded():
    plan = sparse.plan_spmm(_mats()["random"], 16)
    # Random sparsity at avg degree 8: DIA is hopeless and says why.
    assert "dia" in plan.skips
    assert "diagonals" in plan.skips["dia"]
    for cand in plan.candidates:
        assert cand.eligible == (cand.skip_reason is None)


def test_bcsr_inflation_gate():
    """Sparse blocks (D << t^2) must skip BCSR, mirroring mxu_util -> 0."""
    m = blocked(N, t=64, num_blocks=N // 32, nnz_per_block=40, seed=6)
    plan = sparse.plan_spmm(m, 16)
    assert "bcsr" in plan.skips
    assert "inflation" in plan.skips["bcsr"]


def test_plan_summary_and_audit_fields():
    plan = sparse.plan_spmm(_mats()["fem"], 16)
    text = plan.summary()
    assert plan.chosen in text and plan.regime in text
    for cand in plan.candidates:
        if cand.eligible:
            assert cand.ai > 0
            assert cand.predicted_gflops > 0
            # Conversion amortization can only cost, never gain.
            assert cand.amortized_gflops <= cand.predicted_gflops + 1e-9


def test_amortization_improves_with_reuse():
    m = _mats()["fem"]
    lo = sparse.plan_spmm(m, 16, reuse=1).candidate("bcsr")
    hi = sparse.plan_spmm(m, 16, reuse=10_000).candidate("bcsr")
    assert hi.amortized_gflops > lo.amortized_gflops
    assert hi.amortized_gflops == pytest.approx(hi.predicted_gflops,
                                                rel=0.05)


def test_bad_inputs_raise():
    m = _mats()["random"]
    with pytest.raises(ValueError):
        sparse.plan_spmm(m, 16, strategy="dense")
    with pytest.raises(ValueError):
        sparse.Dispatcher(backend="tpu")
    with pytest.raises(ValueError):
        # Forcing DIA on random sparsity: structurally impossible.
        sparse.spmm(m, _b(N, 4), strategy="dia")


# --------------------------------------------------------------------- #
# Caching: plans and conversions are computed once per matrix.
# --------------------------------------------------------------------- #

def test_plan_and_conversion_cached():
    disp = sparse.Dispatcher()
    m = _mats()["fem"]
    p1 = disp.plan(m, 16)
    assert disp.plan(m, 16) is p1                     # plan cache hit
    assert disp.plan(m, 32) is not p1                 # keyed on d
    c1 = disp.convert(m, "csr")
    assert disp.convert(m, "csr") is c1               # conversion cache hit
    b = _b(N, 16)
    out1 = disp.spmm(m, b)
    out2 = disp.spmm(m, b)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_pallas_layouts_shared_between_csr_and_ell():
    """ELL's pallas pick lowers to the CSR kernel; the row-tile packing
    must be prepared once (shared layout_key) and prepare must reuse the
    dispatcher's conversion cache rather than re-converting."""
    disp = sparse.Dispatcher(backend="pallas", bcsr_block=32)
    m = _mats()["random"]
    b = _b(N, 8)
    csr_container = disp.convert(m, "csr")
    out_csr = disp.spmm(m, b, strategy="csr")
    out_ell = disp.spmm(m, b, strategy="ell")
    np.testing.assert_allclose(np.asarray(out_csr), np.asarray(out_ell),
                               rtol=1e-6, atol=1e-6)
    layouts = [k for k in disp._converted if len(k) > 2 and k[1] == "layout"]
    assert len(layouts) == 1                     # one shared packing
    assert disp.convert(m, "csr") is csr_container   # cache, not rebuilt


def test_cache_evicts_on_gc():
    disp = sparse.Dispatcher()
    m = erdos_renyi(N, 4, seed=9)
    disp.plan(m, 16)
    disp.convert(m, "csr")
    assert disp._plans and disp._converted
    del m
    import gc
    gc.collect()
    assert not disp._plans
    assert not disp._converted


# --------------------------------------------------------------------- #
# Learned fallback: the dispatch tree breaks analytic near-ties only.
# --------------------------------------------------------------------- #

def _constant_tree(label):
    """A depth-0 tree that always predicts ``label``."""
    from repro.data.dtree import FEATURES, DecisionTree
    x = np.array([[0.0] * len(FEATURES), [1.0] * len(FEATURES)])
    return DecisionTree(max_depth=0, min_leaf=1).fit(x, [label, label])


def test_analytic_only_without_tree():
    disp = sparse.Dispatcher(backend="jax", tree=False)
    plan = disp.plan(_mats()["random"], 16)
    assert plan.decision_source == "analytic"
    assert plan.decision_path == ()
    assert "decision=analytic" in plan.summary()


def test_tree_breaks_near_tie_with_provenance():
    # A huge margin makes every eligible candidate a near-tie, so the
    # tree's pick must win and stamp its provenance + path.
    disp = sparse.Dispatcher(backend="jax", tree=_constant_tree("csr"),
                             tree_margin=0.99)
    m = _mats()["random"]
    plan = disp.plan(m, 16)
    assert plan.chosen == "csr"
    assert plan.decision_source == "tree"
    assert plan.decision_path and plan.decision_path[-1].startswith(
        "leaf:csr")
    text = plan.summary()
    assert "decision=tree" in text and "~ tree:" in text
    # Numerics are unaffected by who chose the format.
    b = _b(N, 16)
    ref = np.asarray(sparse.formats.coo_to_dense(m)) @ np.asarray(b)
    np.testing.assert_allclose(np.asarray(disp.spmm(m, b)), ref,
                               rtol=5e-4, atol=5e-4)


def test_tree_cannot_overrule_confident_ranking():
    # DIA is policy-ineligible on random sparsity, and with margin=0 no
    # gap qualifies: the analytic winner stands in both cases.
    m = _mats()["random"]
    analytic = sparse.Dispatcher(backend="jax", tree=False).plan(m, 16)
    ineligible = sparse.Dispatcher(backend="jax",
                                   tree=_constant_tree("dia"),
                                   tree_margin=0.99).plan(m, 16)
    assert ineligible.chosen == analytic.chosen
    assert ineligible.decision_source == "analytic"
    zero_margin = sparse.Dispatcher(backend="jax",
                                    tree=_constant_tree("csr"),
                                    tree_margin=0.0).plan(m, 16)
    assert zero_margin.decision_source == "analytic"


def test_tree_ignored_for_forced_strategy():
    disp = sparse.Dispatcher(backend="jax", tree=_constant_tree("csr"),
                             tree_margin=0.99)
    plan = disp.plan(_mats()["random"], 16, strategy="ell")
    assert plan.chosen == "ell"
    assert plan.decision_source == "analytic"


def test_tree_margin_validated():
    with pytest.raises(ValueError, match="tree_margin"):
        sparse.Dispatcher(tree_margin=1.5)


def test_persisted_tree_resolved_lazily(tmp_path, monkeypatch):
    """tree=None loads the store's tree; refits invalidate cached plans
    through refresh_calibration + the fingerprint in the plan key."""
    from repro.data.dtree import DispatchTreeStore
    monkeypatch.setenv("REPRO_CALIBRATION_DIR", str(tmp_path))
    m = _mats()["random"]
    disp = sparse.Dispatcher(backend="jax", tree_margin=0.99)
    before = disp.plan(m, 16)
    assert before.decision_source == "analytic"   # no tree persisted yet
    DispatchTreeStore().save(_constant_tree("csr"), "jax")
    disp.refresh_calibration()
    after = disp.plan(m, 16)
    assert after is not before                    # new plan, not cache hit
    assert after.decision_source == "tree" and after.chosen == "csr"


# --------------------------------------------------------------------- #
# Measured acceptance (slow): auto keeps up with the best fixed format.
# --------------------------------------------------------------------- #

@pytest.mark.slow
def test_auto_within_ratio_of_best_fixed():
    """On the paper suite, auto's wall-clock is >= 0.9x the best fixed
    format per matrix (a fixed format commits to one layout across d),
    checked via the dispatch claims (which exclude the overhead-dominated
    degree-~1 matrices exactly as the seed's regime claims do)."""
    from benchmarks.spmm_suite import dispatch_claims_check, run_suite
    results = run_suite(10e9, scale=12, d_values=(1, 16, 64), repeats=3)
    claims = dispatch_claims_check(results)
    failed = [k for k, v in claims.items() if not v]
    assert not failed, f"dispatch claims failed: {failed}"
