"""Pattern generators + structure classifier (paper Table III regimes)."""
import numpy as np
import pytest

from repro.core import banded, blocked, classify, erdos_renyi, scale_free
from repro.core.classify import (HILL_MIN_DEGREES, block_stats, degree_gini,
                                 hill_alpha, hub_dominance)
from repro.core.patterns import COOMatrix, paper_suite


@pytest.mark.parametrize("gen,expected", [
    (lambda: erdos_renyi(4096, 8, seed=1), "random"),
    (lambda: banded(4096, 1, seed=2), "diagonal"),
    (lambda: banded(4096, 4, fill=0.9, seed=3), "diagonal"),
    (lambda: blocked(4096, t=64, num_blocks=128, nnz_per_block=40, seed=4),
     "blocked"),
    (lambda: scale_free(4096, 16, alpha=2.2, seed=5), "scale_free"),
])
def test_classifier_recovers_regime(gen, expected):
    m = gen()
    report = classify(m)
    assert report.regime == expected, report.stats


def test_generators_deterministic():
    a = erdos_renyi(1024, 4, seed=7)
    b = erdos_renyi(1024, 4, seed=7)
    np.testing.assert_array_equal(a.rows, b.rows)
    np.testing.assert_array_equal(a.vals, b.vals)
    c = erdos_renyi(1024, 4, seed=8)
    assert not np.array_equal(a.rows, c.rows)


def test_coo_invariants():
    for gen in paper_suite(scale=10).values():
        m = gen()
        assert m.nnz == len(m.rows) == len(m.cols) == len(m.vals)
        assert m.rows.min() >= 0 and m.rows.max() < m.n
        assert m.cols.min() >= 0 and m.cols.max() < m.n
        # sorted row-major, unique
        lin = m.rows.astype(np.int64) * m.n + m.cols
        assert np.all(np.diff(lin) > 0)
        ptr = m.row_ptr()
        assert ptr[0] == 0 and ptr[-1] == m.nnz


def test_ideal_diagonal_is_one_per_row():
    m = banded(2048, 1, seed=0)
    assert m.nnz == 2048
    np.testing.assert_array_equal(m.rows, m.cols)


def test_block_stats_match_model():
    """Empirical occupied columns per block ~ the paper's z formula."""
    t, D = 64, 40.0
    m = blocked(2 ** 14, t=t, num_blocks=400, nnz_per_block=D, seed=9)
    stats = block_stats(m, t)
    assert stats["D"] == pytest.approx(D, rel=0.25)
    assert stats["z_emp"] == pytest.approx(stats["z_model"], rel=0.2)


def test_scale_free_tail():
    m = scale_free(2 ** 14, 16, alpha=2.2, seed=11)
    deg = np.bincount(m.rows, minlength=m.n)
    assert degree_gini(deg) > 0.5            # heavy tail
    alpha = hill_alpha(deg)
    assert 1.5 < alpha < 3.5
    # Hubs exist: top 0.1% of rows own a disproportionate share.
    k = max(1, m.n // 1000)
    top = np.sort(deg)[::-1][:k].sum()
    assert top / m.nnz > 10 * (k / m.n)


def test_er_has_no_structure():
    m = erdos_renyi(2 ** 12, 8, seed=13)
    deg = np.bincount(m.rows, minlength=m.n)
    assert degree_gini(deg) < 0.45


def test_er_delivers_exact_density():
    """The draw-then-dedup generator used to lose ~avg_deg/(2n) of its
    entries to birthday collisions; nnz must now equal the request."""
    for n, deg, seed in [(1024, 8, 0), (256, 32, 1), (4096, 64, 2)]:
        m = erdos_renyi(n, deg, seed=seed)
        assert m.nnz == round(n * deg), (n, deg)
        assert m.meta["achieved_nnz"] == m.nnz
        assert m.meta["achieved_avg_degree"] == pytest.approx(deg)
    # Saturating request caps at the dense matrix, no infinite loop.
    assert erdos_renyi(16, 16, seed=3).nnz == 256


def test_generators_record_achieved_density():
    m = banded(512, 4, fill=0.7, seed=5)
    assert m.meta["achieved_nnz"] == m.nnz
    assert m.meta["achieved_avg_degree"] == pytest.approx(m.nnz / m.n)


def test_hill_alpha_small_and_flat_vectors():
    """inf means *no detectable heavy tail* — by design, not by accident
    (the old clamp read deg[size-1], degenerating the estimator)."""
    # Below the documented sample floor: inf, never a spurious estimate.
    assert hill_alpha(np.full(HILL_MIN_DEGREES - 1, 5)) == float("inf")
    assert hill_alpha(np.zeros(100, dtype=int)) == float("inf")
    # Flat degree vectors (uniform/banded) have no tail at any size.
    assert hill_alpha(np.full(10_000, 7)) == float("inf")
    # A genuine power law at corpus scale stays finite and in range:
    # the old clamp's failure mode was inf exactly here.
    deg = np.bincount(scale_free(256, 8, alpha=2.2, seed=8).rows,
                      minlength=256)
    assert 1.5 < hill_alpha(deg) < 3.5


def test_hub_dominance_separates_hubs_from_uniform():
    assert hub_dominance(np.full(1000, 5)) == pytest.approx(1.0)
    assert hub_dominance(np.zeros(10)) == 0.0
    sf = np.bincount(scale_free(256, 8, alpha=2.1, seed=8).rows,
                     minlength=256)
    er = np.bincount(erdos_renyi(256, 8, seed=1).rows, minlength=256)
    assert hub_dominance(sf) > 7.0 > hub_dominance(er)


def _transpose(m: COOMatrix) -> COOMatrix:
    lin = m.cols.astype(np.int64) * m.n + m.rows
    order = np.argsort(lin, kind="stable")
    return COOMatrix(n=m.n, rows=m.cols[order], cols=m.rows[order],
                     vals=m.vals[order], pattern=m.pattern, meta={})


@pytest.mark.parametrize("n,deg", [(256, 8), (4096, 16)])
def test_classifier_detects_column_hubs(n, deg):
    """Transposed scale-free: uniform row degrees, heavy column tail.
    Row-only degree statistics classified this as ``random``."""
    mt = _transpose(scale_free(n, deg, alpha=2.2, seed=5))
    report = classify(mt)
    assert report.regime == "scale_free", report.stats
    assert report.stats["tail_axis"] == "col"
    assert report.stats["col_gini"] > report.stats["row_gini"]


def test_classifier_small_matrix_regimes():
    """Corpus-scale (n of a few hundred) versions of every regime: the
    sizes the vendored samples live at, where the pre-fix classifier
    sent banded, blocked, and scale-free matrices all to ``random``."""
    cases = [
        (erdos_renyi(256, 8, seed=1), "random"),
        (banded(224, 5, fill=0.85, seed=5), "diagonal"),
        (blocked(256, t=32, num_blocks=16, nnz_per_block=256, seed=6),
         "blocked"),
        (scale_free(256, 8, alpha=2.1, seed=8), "scale_free"),
    ]
    for m, expected in cases:
        assert classify(m).regime == expected, (m.pattern, m.n)
