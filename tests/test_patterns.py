"""Pattern generators + structure classifier (paper Table III regimes)."""
import numpy as np
import pytest

from repro.core import banded, blocked, classify, erdos_renyi, scale_free
from repro.core.classify import block_stats, degree_gini, hill_alpha
from repro.core.patterns import paper_suite


@pytest.mark.parametrize("gen,expected", [
    (lambda: erdos_renyi(4096, 8, seed=1), "random"),
    (lambda: banded(4096, 1, seed=2), "diagonal"),
    (lambda: banded(4096, 4, fill=0.9, seed=3), "diagonal"),
    (lambda: blocked(4096, t=64, num_blocks=128, nnz_per_block=40, seed=4),
     "blocked"),
    (lambda: scale_free(4096, 16, alpha=2.2, seed=5), "scale_free"),
])
def test_classifier_recovers_regime(gen, expected):
    m = gen()
    report = classify(m)
    assert report.regime == expected, report.stats


def test_generators_deterministic():
    a = erdos_renyi(1024, 4, seed=7)
    b = erdos_renyi(1024, 4, seed=7)
    np.testing.assert_array_equal(a.rows, b.rows)
    np.testing.assert_array_equal(a.vals, b.vals)
    c = erdos_renyi(1024, 4, seed=8)
    assert not np.array_equal(a.rows, c.rows)


def test_coo_invariants():
    for gen in paper_suite(scale=10).values():
        m = gen()
        assert m.nnz == len(m.rows) == len(m.cols) == len(m.vals)
        assert m.rows.min() >= 0 and m.rows.max() < m.n
        assert m.cols.min() >= 0 and m.cols.max() < m.n
        # sorted row-major, unique
        lin = m.rows.astype(np.int64) * m.n + m.cols
        assert np.all(np.diff(lin) > 0)
        ptr = m.row_ptr()
        assert ptr[0] == 0 and ptr[-1] == m.nnz


def test_ideal_diagonal_is_one_per_row():
    m = banded(2048, 1, seed=0)
    assert m.nnz == 2048
    np.testing.assert_array_equal(m.rows, m.cols)


def test_block_stats_match_model():
    """Empirical occupied columns per block ~ the paper's z formula."""
    t, D = 64, 40.0
    m = blocked(2 ** 14, t=t, num_blocks=400, nnz_per_block=D, seed=9)
    stats = block_stats(m, t)
    assert stats["D"] == pytest.approx(D, rel=0.25)
    assert stats["z_emp"] == pytest.approx(stats["z_model"], rel=0.2)


def test_scale_free_tail():
    m = scale_free(2 ** 14, 16, alpha=2.2, seed=11)
    deg = np.bincount(m.rows, minlength=m.n)
    assert degree_gini(deg) > 0.5            # heavy tail
    alpha = hill_alpha(deg)
    assert 1.5 < alpha < 3.5
    # Hubs exist: top 0.1% of rows own a disproportionate share.
    k = max(1, m.n // 1000)
    top = np.sort(deg)[::-1][:k].sum()
    assert top / m.nnz > 10 * (k / m.n)


def test_er_has_no_structure():
    m = erdos_renyi(2 ** 12, 8, seed=13)
    deg = np.bincount(m.rows, minlength=m.n)
    assert degree_gini(deg) < 0.45
