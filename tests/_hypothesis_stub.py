"""Minimal deterministic stand-in for ``hypothesis`` when it is absent.

``hypothesis`` is a declared dev dependency (see pyproject.toml) and CI
installs it; this stub only keeps the property tests collectable and
meaningful on stripped environments (like this container) by running each
``@given`` test on a fixed budget of deterministically sampled examples.
It implements exactly the strategy surface the test-suite uses:
``integers``, ``floats``, ``sampled_from``, and ``lists``.
"""
from __future__ import annotations

import functools
import inspect
import os
import sys

import numpy as np

_DEFAULT_MAX_EXAMPLES = 12

# On CI the real hypothesis is a declared dev dependency; falling back to
# this stub there means the fuzz coverage silently shrank to the fixed
# example budget.  Say so once, loudly, in the job log.
if os.environ.get("CI"):
    print("WARNING: tests/_hypothesis_stub.py is active (real 'hypothesis' "
          "not importable) — property tests run on a fixed deterministic "
          "budget instead of full fuzzing.", file=sys.stderr)


class _Strategy:
    def sample(self, rng: np.random.Generator):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def sample(self, rng):
        # Bias toward the endpoints: property failures cluster there.
        if rng.uniform() < 0.25:
            return self.lo if rng.uniform() < 0.5 else self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, min_value, max_value, allow_nan=None):
        self.lo, self.hi = float(min_value), float(max_value)

    def sample(self, rng):
        if rng.uniform() < 0.25:
            return self.lo if rng.uniform() < 0.5 else self.hi
        return float(rng.uniform(self.lo, self.hi))


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def sample(self, rng):
        return self.options[int(rng.integers(len(self.options)))]


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=None):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 16

    def sample(self, rng):
        size = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.sample(rng) for _ in range(size)]


class _StrategiesNamespace:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value, max_value, allow_nan=None):
        return _Floats(min_value, max_value, allow_nan)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        return _Lists(elements, min_size=min_size, max_size=max_size)


st = _StrategiesNamespace()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kwarg_strategies):
    def deco(fn):
        budget = min(getattr(fn, "_stub_max_examples",
                             _DEFAULT_MAX_EXAMPLES),
                     _DEFAULT_MAX_EXAMPLES)
        sig = inspect.signature(fn)
        positional = [p for p in sig.parameters if p not in kwarg_strategies]
        supplied = set(kwarg_strategies) | set(
            positional[:len(arg_strategies)])

        @functools.wraps(fn)
        def runner(*call_args, **call_kwargs):
            rng = np.random.default_rng(0)
            for _ in range(budget):
                kwargs = dict(call_kwargs)
                kwargs.update({name: s.sample(rng)
                               for name, s in kwarg_strategies.items()})
                kwargs.update({name: s.sample(rng) for name, s in
                               zip(positional, arg_strategies)})
                fn(*call_args, **kwargs)

        # Strategy-supplied params must not look like pytest fixtures.
        runner.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in supplied])
        del runner.__wrapped__
        return runner
    return deco
