"""SpMM implementations vs dense reference, across formats x patterns x d."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.core import banded, blocked, erdos_renyi, scale_free

PATTERNS = {
    "random": lambda n: erdos_renyi(n, 6, seed=1),
    "diagonal": lambda n: banded(n, 3, seed=2),
    "blocked": lambda n: blocked(n, t=16, num_blocks=n // 8,
                                 nnz_per_block=12, seed=3),
    "scale_free": lambda n: scale_free(n, 8, seed=4),
}


def _b(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


@pytest.mark.parametrize("pattern", sorted(PATTERNS))
@pytest.mark.parametrize("d", [1, 4, 16])
def test_csr_ell_bcsr_allclose(pattern, d):
    n = 256
    m = PATTERNS[pattern](n)
    dense = sparse.coo_to_dense(m)
    b = _b(n, d)
    ref = dense @ b
    outs = {
        "csr": sparse.csr_spmm(sparse.coo_to_csr(m), b),
        "ell": sparse.ell_spmm(sparse.coo_to_ell(m), b),
        "bcsr": sparse.bcsr_spmm(sparse.coo_to_bcsr(m, 16), b),
        "bcsr_scan": sparse.bcsr_spmm_scan(sparse.coo_to_bcsr(m, 16), b),
    }
    for name, out in outs.items():
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"{pattern}/{name}/d={d}")


@pytest.mark.parametrize("bandwidth", [1, 3, 7])
def test_dia_allclose(bandwidth):
    n = 256
    m = banded(n, bandwidth, seed=5)
    ref = sparse.coo_to_dense(m) @ _b(n, 8)
    out = sparse.dia_spmm(sparse.coo_to_dia(m), _b(n, 8))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_dia_rejects_unbanded():
    m = erdos_renyi(256, 8, seed=6)
    with pytest.raises(ValueError):
        sparse.coo_to_dia(m, max_offsets=16)


def test_bcsr_requires_divisible_block():
    m = erdos_renyi(250, 4, seed=7)
    with pytest.raises(ValueError):
        sparse.coo_to_bcsr(m, 16)


def test_formats_preserve_nnz():
    m = erdos_renyi(256, 6, seed=8)
    csr = sparse.coo_to_csr(m)
    assert csr.nnz == m.nnz
    bcsr = sparse.coo_to_bcsr(m, 16)
    assert bcsr.nnz == m.nnz
    assert float(jnp.sum(jnp.abs(bcsr.blocks) > 0)) == m.nnz
