"""Kernel registry: completeness, numerics per spec, VMEM models."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels, sparse
from repro.core import banded, blocked, erdos_renyi
from repro.core.hardware import HOST_CPU, TPU_V5E
from repro.kernels import registry

N = 256


def _mats():
    return {
        "csr": erdos_renyi(N, 6, seed=1),
        "ell": erdos_renyi(N, 6, seed=2),
        "bcsr": blocked(N, t=32, num_blocks=24, nnz_per_block=300, seed=3),
        "dia": banded(N, 3, fill=0.9, seed=4),
    }


def _b(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


# --------------------------------------------------------------------- #
# Completeness: the README feature matrix must resolve end to end.
# --------------------------------------------------------------------- #

def test_every_dispatch_pair_registered():
    """Every (format, backend) pair the dispatcher can choose resolves."""
    for fmt in sparse.FORMATS:
        for backend in registry.BACKENDS:
            spec = registry.get(fmt, backend)
            assert spec.key == (fmt, backend)
            assert spec.description
    assert registry.get("grouped", "pallas").format == "grouped"
    matrix = registry.feature_matrix()
    assert set(matrix) >= {(f, b) for f in sparse.FORMATS
                           for b in registry.BACKENDS}
    assert set(registry.formats_for("jax")) == set(sparse.FORMATS)
    assert set(registry.formats_for("pallas")) == \
        set(sparse.FORMATS) | {"grouped"}


def test_get_unknown_pair_lists_available():
    with pytest.raises(KeyError, match="available"):
        registry.get("csr", "cuda")
    with pytest.raises(ValueError, match="already registered"):
        registry.register(registry.get("csr", "jax"))


def test_every_spmm_spec_matches_dense():
    """bind -> run agrees with the dense reference for every pair."""
    ctx = registry.KernelContext(bcsr_block=32)
    b = _b(N, 16)
    for fmt, m in _mats().items():
        dense = np.asarray(sparse.coo_to_dense(m)) @ np.asarray(b)
        for backend in registry.BACKENDS:
            run = registry.get(fmt, backend).bind(m, ctx)
            np.testing.assert_allclose(
                np.asarray(run(b)), dense, rtol=5e-4, atol=5e-4,
                err_msg=f"{fmt}/{backend}")


def test_registry_spmm_one_call():
    m = _mats()["csr"]
    b = _b(N, 8)
    out = registry.spmm(m, b, format="csr", backend="pallas")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(sparse.coo_to_dense(m)) @ np.asarray(b),
        rtol=5e-4, atol=5e-4)


def test_grouped_spec_roundtrip():
    """The MoE grouped-matmul spec: bind carries (w, gids, tiles)."""
    from repro.kernels import ref
    E, bm, K, Nn = 4, 32, 64, 64
    gids = jnp.asarray([0, 1, 1, 3], jnp.int32)
    x = _b(4 * bm, K, seed=5)
    w = jnp.asarray(np.random.default_rng(6).normal(
        size=(E, K, Nn)).astype(np.float32))
    spec = registry.get("grouped", "pallas")
    run = spec.bind((w, gids, bm, 64, 64), registry.KernelContext())
    np.testing.assert_allclose(
        np.asarray(run(x)), np.asarray(ref.grouped_matmul_ref(x, w, gids,
                                                              bm=bm)),
        rtol=2e-3, atol=2e-3)
    roof = spec.estimate((w, gids, bm, 64, 64), 0, registry.KernelContext())
    assert roof.mxu_utilization == 1.0 and roof.ai > 0


# --------------------------------------------------------------------- #
# Estimates and VMEM footprints.
# --------------------------------------------------------------------- #

def test_estimates_have_roofline_fields():
    ctx = registry.KernelContext(hardware=TPU_V5E, bcsr_block=32)
    for fmt, m in _mats().items():
        for backend in registry.BACKENDS:
            r = kernels.KernelRoofline, registry.get(fmt, backend)
            est = r[1].estimate(m, 64, ctx)
            assert est.ai > 0 and est.useful_flops > 0
            assert 0 < est.mxu_utilization <= 1
            assert est.useful_flops <= est.mxu_flops + 1e-6
            assert est.attainable_flops_per_s > 0


def test_vmem_footprints():
    ctx = registry.KernelContext(hardware=TPU_V5E, bcsr_block=32)
    for fmt in sparse.FORMATS:
        assert registry.get(fmt, "jax").vmem_footprint(N, 64, ctx) == 0
        fp = registry.get(fmt, "pallas").vmem_footprint(N, 64, ctx)
        assert 0 < fp <= TPU_V5E.vmem_bytes
    # The streamed CSR footprint must respect a small VMEM budget even
    # for an n where whole-B residency would blow it by orders of
    # magnitude.  (The floor is the [chunk, bd] gather scratch, ~256 KiB
    # at bd=512 — B streaming cannot shrink that term.)
    tiny = dataclasses.replace(TPU_V5E, vmem_bytes=2 * 2 ** 20)
    tctx = registry.KernelContext(hardware=tiny)
    n_big = 1_000_000
    assert n_big * 512 * 4 > tiny.vmem_bytes        # whole B would not fit
    fp = registry.get("csr", "pallas").vmem_footprint(n_big, 512, tctx)
    assert fp <= tiny.vmem_bytes


def test_choose_b_tile_policy():
    # Plenty of VMEM: hold B whole (None = unstreamed layout).
    assert registry.choose_b_tile(512, 128 * 2 ** 20) is None
    # Tight VMEM: slab shrinks, stays a multiple of 8, floors at 8.
    bt = registry.choose_b_tile(10_000, 2 ** 20, bd=512)
    assert bt is not None and bt % 8 == 0 and bt < 10_000
    assert registry.choose_b_tile(10_000, 1024, bd=512) == 8
    # No budget information: behave as before (whole B).
    assert registry.choose_b_tile(512, 0) is None


def test_context_resolves_b_tile_override():
    ctx = registry.KernelContext(b_tile=64)
    assert ctx.resolve_b_tile(256) == 64
    assert ctx.resolve_b_tile(32) is None        # override >= n: whole B
    auto = registry.KernelContext(
        hardware=dataclasses.replace(HOST_CPU, vmem_bytes=2 ** 16))
    assert auto.resolve_b_tile(100_000) == \
        registry.choose_b_tile(100_000, 2 ** 16)


def test_plan_d_repacks_b_slab():
    """Per-d slab re-packing: small planned widths get taller B slabs.

    The default bd=512 charges the VMEM budget for the widest d-tile;
    a plan that knows d=8 hosts a 64x-narrower slab and so fits 64x the
    rows (capped by n / whole-B residency).
    """
    tight = dataclasses.replace(HOST_CPU, vmem_bytes=2 ** 20)
    n = 100_000
    wide = registry.KernelContext(hardware=tight)              # bd=512
    narrow = registry.KernelContext(hardware=tight, plan_d=8)  # bd=8
    t_wide, t_narrow = wide.resolve_b_tile(n), narrow.resolve_b_tile(n)
    assert t_wide is not None and t_narrow is not None
    assert t_narrow == registry.choose_b_tile(n, 2 ** 20, bd=8)
    assert t_narrow > t_wide
    # plan_d=None preserves the legacy conservative sizing exactly.
    assert t_wide == registry.choose_b_tile(n, 2 ** 20, bd=512)
    # Non-power-of-two widths route through the kernel's actual d-tile.
    d24 = registry.KernelContext(hardware=tight, plan_d=24)
    assert d24.resolve_b_tile(n) == registry.choose_b_tile(
        n, 2 ** 20, bd=registry.pallas_block_d(24))
    # An explicit override still wins over the planned width.
    forced = registry.KernelContext(hardware=tight, plan_d=8, b_tile=64)
    assert forced.resolve_b_tile(n) == 64
    # With a taller slab the whole-B threshold moves: a matrix that
    # streams at bd=512 can be fully resident at bd=8.
    n_small = registry.choose_b_tile(4096, 2 ** 20, bd=512)
    assert n_small is not None                   # streams under wide tile
    assert registry.KernelContext(hardware=tight,
                                  plan_d=8).resolve_b_tile(4096) is None


def test_registry_version_current():
    """REGISTRY_VERSION gates calibration staleness; must be an int >= 2
    (v2 introduced per-d slab re-packing)."""
    assert isinstance(registry.REGISTRY_VERSION, int)
    assert registry.REGISTRY_VERSION >= 2
