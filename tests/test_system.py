"""End-to-end system behaviour: the public API wired together.

A miniature of the production path: config -> pipeline -> sharded-ish
train steps -> checkpoint -> serve, all on the reduced llama config.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, list_archs
from repro.configs.base import SHAPES, ShapeConfig, all_cells
from repro.data.pipeline import DataConfig, Pipeline
from repro.optim import adamw
from repro.train.train_step import (make_prefill_step, make_serve_step,
                                    make_train_step, softmax_xent)

# Whole-module integration tests: excluded from tier-1 (run nightly / -m slow).
pytestmark = pytest.mark.slow


def test_cell_matrix_shape():
    """10 archs; every arch exposes >= 3 shape cells; skips documented."""
    archs = list_archs()
    assert len(archs) == 10
    cells = list(all_cells())
    assert len(cells) == 33            # 40 assigned - 7 long_500k skips
    long_runners = [a for a, s in cells if s == "long_500k"]
    assert sorted(long_runners) == [
        "falcon-mamba-7b", "gemma3-12b", "recurrentgemma-9b"]


def test_shapes_table():
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524_288
    assert SHAPES["decode_32k"].kind == "decode"


def test_train_checkpoint_serve_loop(tmp_path):
    cfg = get_config("llama3.2-1b").reduced()
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    pipe = Pipeline(cfg, shape, DataConfig(seed=0))
    step, _ = make_train_step(cfg, shape,
                              schedule_kwargs={"warmup_steps": 2,
                                               "total_steps": 1000})
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params, adamw.AdamWConfig())
    losses = []
    for s in range(6):
        batch = {k: jnp.asarray(v) for k, v in
                 pipe.batch_for_step(s).items()}
        params, opt, m = step(params, opt, batch, jnp.int32(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]

    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"params": params})
    restored = ck.restore()["params"]

    # Serve with the restored params: greedy-decode a few tokens.
    cache = models.init_cache(cfg, 2, 16)
    tok = jnp.asarray([2, 3], jnp.int32)
    for pos in range(4):
        logits, cache = models.decode_step(cfg, restored, cache, tok,
                                           jnp.int32(pos))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(
            jnp.int32)
    assert tok.shape == (2,)
    assert int(tok.max()) < cfg.vocab_size


def test_softmax_xent_masks_padded_vocab():
    logits = jnp.zeros((1, 2, 8))
    labels = jnp.asarray([[1, 2]], jnp.int32)
    full = softmax_xent(logits, labels)
    masked = softmax_xent(logits, labels, vocab=4)
    assert float(masked) == pytest.approx(np.log(4.0), rel=1e-5)
    assert float(full) == pytest.approx(np.log(8.0), rel=1e-5)


def test_prefill_and_serve_factories_single_device():
    cfg = get_config("gemma3-12b").reduced()
    shape = ShapeConfig("tiny", seq_len=32, global_batch=2, kind="prefill")
    prefill, _ = make_prefill_step(cfg, shape)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    logits = prefill(params, {"tokens": jnp.ones((2, 32), jnp.int32)})
    assert logits.shape == (2, 32, cfg.padded_vocab)

    dshape = ShapeConfig("tinyd", seq_len=32, global_batch=2, kind="decode")
    serve, _ = make_serve_step(cfg, dshape)
    cache = models.init_cache(cfg, 2, 32)
    lg, cache2 = serve(params, cache, jnp.ones((2,), jnp.int32),
                       jnp.int32(31))
    assert lg.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg)).all()
