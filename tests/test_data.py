"""Stateless data pipeline: determinism + modality stubs."""
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, Pipeline

SHAPE = ShapeConfig("tiny", seq_len=16, global_batch=4, kind="train")


def test_batches_deterministic_per_step():
    cfg = get_config("llama3.2-1b").reduced()
    p1 = Pipeline(cfg, SHAPE, DataConfig(seed=3))
    p2 = Pipeline(cfg, SHAPE, DataConfig(seed=3))
    b1 = p1.batch_for_step(5)
    b2 = p2.batch_for_step(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch_for_step(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_config("llama3.2-1b").reduced()
    p = Pipeline(cfg, SHAPE, DataConfig(seed=0))
    b = p.batch_for_step(0)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)
    assert b["tokens"].max() < cfg.vocab_size


def test_vlm_stubs():
    cfg = get_config("qwen2-vl-7b").reduced()
    p = Pipeline(cfg, SHAPE, DataConfig(seed=0))
    b = p.batch_for_step(0)
    assert b["mm_embeds"].shape[0] == 4
    assert b["mm_embeds"].shape[2] == cfg.d_model
    assert b["positions_3d"].shape == (3, 4, 16)


def test_encdec_stubs():
    cfg = get_config("whisper-base").reduced()
    p = Pipeline(cfg, SHAPE, DataConfig(seed=0))
    b = p.batch_for_step(0)
    assert b["frames"].shape == (4, cfg.encoder_seq, cfg.d_model)


def test_memmap_source(tmp_path):
    cfg = get_config("llama3.2-1b").reduced()
    path = tmp_path / "tokens.bin"
    tokens = np.arange(10000, dtype=np.uint16) % cfg.vocab_size
    tokens.tofile(path)
    p = Pipeline(cfg, SHAPE, DataConfig(seed=0, path=str(path)))
    b = p.batch_for_step(1)
    assert b["tokens"].shape == (4, 16)
    # consecutive tokens from the flat file
    row = b["tokens"][0]
    assert np.all(np.diff(row) == 1)
