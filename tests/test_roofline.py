"""Distributed roofline terms + analyzer on dry-run records."""
import glob
import json

import pytest

from repro.core.analyzer import (analyze_record, format_roofline_table,
                                 sparse_component_ai)
from repro.core.hardware import TPU_V5E
from repro.core.roofline import DistributedRoofline


def _record(flops=1e15, byts=1e12, coll=1e10, chips=256):
    return {
        "arch": "x", "shape": "train_4k", "mesh": "16x16",
        "chips": chips,
        "cost": {"flops_per_device": flops / chips,
                 "bytes_per_device": byts / chips},
        "collectives": {"total": coll / chips},
        "model_flops": flops * 0.6,
    }


def test_three_terms():
    roof = DistributedRoofline(
        name="t", chips=256, hlo_flops=1e15, hlo_bytes=1e12,
        collective_bytes=1e10, hardware=TPU_V5E, model_flops=6e14)
    assert roof.compute_s == pytest.approx(1e15 / (256 * 197e12))
    assert roof.memory_s == pytest.approx(1e12 / (256 * 819e9))
    assert roof.collective_s == pytest.approx(1e10 / (256 * 50e9))
    assert roof.dominant == "compute"
    assert roof.useful_compute_ratio == pytest.approx(0.6)
    assert 0 < roof.mfu_upper_bound <= 1


def test_analyze_record_roundtrip():
    rec = analyze_record(_record())
    r = rec["roofline"]
    assert r["dominant"] in ("compute", "memory", "collective")
    assert "hint" in r
    table = format_roofline_table([rec])
    assert "train_4k" in table and "|" in table


def test_dominant_switches():
    mem = analyze_record(_record(flops=1e12, byts=1e14))
    assert mem["roofline"]["dominant"] == "memory"
    assert "AI" in mem["roofline"]["hint"] or \
        "memory" in mem["roofline"]["hint"]
    coll = analyze_record(_record(flops=1e12, byts=1e9, coll=1e13))
    assert coll["roofline"]["dominant"] == "collective"


def test_sparse_component_blocked():
    comp = {"name": "moe", "regime": "blocked_tpu", "n": 8192,
            "nnz": 8192 * 128, "t": 128, "num_blocks": 64, "d": 4096}
    out = sparse_component_ai(comp)
    assert out["mxu_utilization"] == 1.0
    assert out["ai"] > 0


def test_real_dryrun_records_if_present():
    """Schema validation over whatever the background sweep has produced."""
    paths = glob.glob("experiments/dryrun/*.json")
    if not paths:
        pytest.skip("no dry-run records yet")
    for p in paths[:10]:
        with open(p) as f:
            rec = json.load(f)
        out = analyze_record(rec)
        r = out["roofline"]
        # batch-1 decode steps can lower every matvec into reduce fusions
        # on CPU, leaving zero counted dot FLOPs — memory term still real.
        assert r["compute_s"] >= 0
        if rec["step_kind"] != "decode":
            assert r["compute_s"] > 0
        assert r["memory_s"] > 0
        assert rec["chips"] in (256, 512)
        assert rec["memory"]["temp_size_in_bytes"] >= 0
