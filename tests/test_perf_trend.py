"""tools/perf_trend.py: CSV parsing, regression detection, soft-warn exit."""

import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent

HEADER = ("matrix,pattern,impl,d,nnz,gflops,ai_model,"
          "predicted_gflops,roofline_fraction,chosen")


def _load():
    spec = importlib.util.spec_from_file_location(
        "perf_trend", ROOT / "tools" / "perf_trend.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _csv(tmp_path, name, rows):
    path = tmp_path / name
    path.write_text("\n".join([HEADER] + rows) + "\n")
    return path


def _row(matrix, impl, d, gflops):
    return f"{matrix},uniform,{impl},{d},1000,{gflops},0.1,1.0,0.5,csr"


def test_parse_and_compare(tmp_path):
    pt = _load()
    prev = pt.parse_csv(_csv(tmp_path, "prev.csv", [
        _row("er", "csr", 16, 2.0),
        _row("er", "auto", 16, 2.0),
        _row("band", "stream_r8", 64, 4.0),
        "malformed,row",
    ]))
    # Pre-dtype-column CSVs key as f32i32 (what those cells ran at).
    assert prev[("er", "csr", "16", "f32i32")] == 2.0
    assert len(prev) == 3                      # malformed row skipped
    cur = pt.parse_csv(_csv(tmp_path, "cur.csv", [
        _row("er", "csr", 16, 1.0),            # 50% drop -> regression
        _row("er", "auto", 16, 1.85),          # 7.5% drop -> within noise
        _row("band", "stream_r8", 64, 8.0),    # improvement
        _row("new", "dia", 4, 1.0),            # no baseline -> ignored
    ]))
    regs = pt.compare(prev, cur, threshold=0.10)
    assert [(k, round(drop, 2)) for k, _, _, drop in regs] == \
        [(("er", "csr", "16", "f32i32"), 0.5)]


def test_main_soft_warn_vs_strict(tmp_path, capsys):
    pt = _load()
    prev = _csv(tmp_path, "prev.csv", [_row("er", "csr", 16, 2.0)])
    cur = _csv(tmp_path, "cur.csv", [_row("er", "csr", 16, 1.0)])
    # Default: report + GitHub annotation, but exit 0 (soft warn).
    assert pt.main(["--previous", str(prev), "--current", str(cur)]) == 0
    out = capsys.readouterr().out
    assert "::warning" in out and "REGRESSION" in out
    # Strict: same comparison fails the job.
    assert pt.main(["--previous", str(prev), "--current", str(cur),
                    "--strict"]) == 1


def test_main_handles_missing_baseline(tmp_path, capsys):
    pt = _load()
    cur = _csv(tmp_path, "cur.csv", [_row("er", "csr", 16, 1.0)])
    assert pt.main(["--previous", str(tmp_path / "nope.csv"),
                    "--current", str(cur)]) == 0
    assert "no readable baseline" in capsys.readouterr().out
    # Missing current is a hard error (the smoke run should have made it).
    assert pt.main(["--previous", str(cur),
                    "--current", str(tmp_path / "gone.csv")]) == 1


def test_trend_window_median_baseline(tmp_path):
    """Multi-run window: each cell's baseline is its median over the runs."""
    pt = _load()
    runs = [
        _csv(tmp_path, "r1.csv", [_row("er", "csr", 16, 2.0)]),
        _csv(tmp_path, "r2.csv", [_row("er", "csr", 16, 10.0)]),   # spike
        _csv(tmp_path, "r3.csv", [_row("er", "csr", 16, 2.2),
                                  _row("band", "shard8_all_gather", 64,
                                       4.0)]),
    ]
    prev = pt.baseline_window([pathlib.Path(p) for p in runs])
    assert prev[("er", "csr", "16", "f32i32")] == 2.2   # median, not spike
    assert prev[("band", "shard8_all_gather", "64",
                 "f32i32")] == 4.0                      # partial cell

    # 2.0 is an 80% drop vs the spike but <10% vs the median: the window
    # is what makes --strict survivable.
    cur = _csv(tmp_path, "cur.csv", [_row("er", "csr", 16, 2.0)])
    argv = ["--previous"] + [str(p) for p in runs] + \
        ["--current", str(cur), "--strict"]
    assert pt.main(argv) == 0
    # Against the spike alone the same run hard-fails.
    assert pt.main(["--previous", str(runs[1]), "--current", str(cur),
                    "--strict"]) == 1


def test_dtype_column_keys_cells_separately(tmp_path):
    """bf16-lane rows never trend against fp32 baselines: the dtype
    column is part of the cell key, and rows from CSVs written before
    the column existed land under f32i32."""
    pt = _load()
    dt_header = HEADER + ",dtype"
    path = tmp_path / "mixed.csv"
    path.write_text("\n".join([
        dt_header,
        _row("er", "csr", 16, 2.0) + ",f32i32",
        _row("er", "csr", 16, 1.0) + ",bf16i32",
    ]) + "\n")
    prev = pt.parse_csv(path)
    assert prev[("er", "csr", "16", "f32i32")] == 2.0
    assert prev[("er", "csr", "16", "bf16i32")] == 1.0
    # A bf16 current run compares only against the bf16 cell: the 50%
    # gap to the fp32 baseline is not a regression.
    cur = tmp_path / "cur.csv"
    cur.write_text("\n".join([dt_header,
                              _row("er", "csr", 16, 1.0) + ",bf16i32"])
                   + "\n")
    assert pt.compare(prev, pt.parse_csv(cur), threshold=0.10) == []


def test_main_disjoint_schemas(tmp_path, capsys):
    pt = _load()
    prev = _csv(tmp_path, "prev.csv", [_row("old", "csr", 16, 2.0)])
    cur = _csv(tmp_path, "cur.csv", [_row("new", "csr", 16, 1.0)])
    assert pt.main(["--previous", str(prev), "--current", str(cur)]) == 0
    assert "no comparable cells" in capsys.readouterr().out
