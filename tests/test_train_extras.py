"""Coverage for the perf-iteration additions: chunked loss, causal-impl
switch, sharding-policy helpers, HLO contributor diagnostics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.hlo_flops import top_contributors
from repro.launch.sharding import dp_axes_for_batch, validate_spec
from repro.models import attention as ATT
from repro.optim import adamw
from repro.train.train_step import make_train_step

# Whole-module integration tests: excluded from tier-1 (run nightly / -m slow).
pytestmark = pytest.mark.slow


def _setup(arch="gemma-2b"):
    cfg = get_config(arch).reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params, adamw.AdamWConfig())
    batch = {"tokens": jnp.ones((2, 32), jnp.int32) * 3,
             "labels": jnp.ones((2, 32), jnp.int32) * 4}
    return cfg, params, opt, batch


def test_chunked_loss_matches_plain():
    cfg, params, opt, batch = _setup()
    shape = ShapeConfig("t", 32, 2, "train")
    plain, _ = make_train_step(cfg, shape)
    chunked, _ = make_train_step(cfg, shape, chunked_loss=True)
    _, _, m1 = plain(params, opt, batch, jnp.int32(0))
    cfg2, params2, opt2, _ = _setup()
    _, _, m2 = chunked(params2, opt2, batch, jnp.int32(0))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(
        float(m2["grad_norm"]), rel=1e-3)


def test_grad_accum_matches_single_batch():
    cfg, params, opt, batch = _setup()
    shape = ShapeConfig("t", 32, 2, "train")
    s1, _ = make_train_step(cfg, shape, grad_accum=1)
    s2, _ = make_train_step(cfg, shape, grad_accum=2)
    _, _, m1 = s1(params, opt, batch, jnp.int32(0))
    cfg2, params2, opt2, _ = _setup()
    _, _, m2 = s2(params2, opt2, batch, jnp.int32(0))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)


def test_causal_impl_switch_roundtrip():
    ATT.set_causal_impl("triangle")
    assert ATT.CAUSAL_IMPL == "triangle"
    ATT.set_causal_impl("masked")
    assert ATT.CAUSAL_IMPL == "masked"
    with pytest.raises(AssertionError):
        ATT.set_causal_impl("bogus")


def test_fused_projections_equivalent_math():
    from repro.models.model import set_fused_projections
    cfg = get_config("llama3.2-1b").reduced()
    batch = {"tokens": jnp.ones((1, 16), jnp.int32) * 5}
    set_fused_projections(True)
    try:
        params_f = models.init_params(cfg, jax.random.PRNGKey(0))
        assert "wqkv" in jax.tree_util.tree_flatten_with_path(
            params_f)[0][0][0][0].key or True
        logits = models.forward(cfg, params_f, batch)
        assert np.isfinite(np.asarray(logits)).all()
    finally:
        set_fused_projections(False)
    params_u = models.init_params(cfg, jax.random.PRNGKey(0))
    logits_u = models.forward(cfg, params_u, batch)
    assert np.isfinite(np.asarray(logits_u)).all()


def test_validate_spec_drops_indivisible():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16, "data": 16}
    spec = validate_spec(P("model", "data"), (51865, 512), FakeMesh())
    assert tuple(spec) == (None, "data")
    spec2 = validate_spec(P("model", None), (256000, 64), FakeMesh())
    assert tuple(spec2) == ("model", None)
    del mesh


def test_dp_axes_for_batch_greedy():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    taken, rest = dp_axes_for_batch(FakeMesh(), 256)
    assert taken == ("pod", "data") and rest == ()
    taken, rest = dp_axes_for_batch(FakeMesh(), 1)
    assert taken == () and rest == ("pod", "data")
    taken, rest = dp_axes_for_batch(FakeMesh(), 16)
    assert taken == ("pod",) and rest == ("data",)


def test_top_contributors_flops():
    x = jnp.ones((32, 64))
    w = jnp.ones((64, 64))

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out

    txt = jax.jit(f).lower(x, w).compile().as_text()
    rows = top_contributors(txt, "flops", k=3)
    assert rows and rows[0][0] == pytest.approx(2 * 32 * 64 * 64 * 5)
    brows = top_contributors(txt, "bytes", k=3)
    assert brows and brows[0][0] > 0
