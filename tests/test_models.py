"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import SHAPES, get_config, list_archs

# Whole-module integration tests: excluded from tier-1 (run nightly / -m slow).
pytestmark = pytest.mark.slow


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(2, cfg.vocab_size - 1, size=(B, S)).astype(np.int32))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(
            size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * .1)
    if cfg.family == "vlm":
        batch["mm_embeds"] = jnp.asarray(rng.normal(
            size=(B, 8, cfg.d_model)).astype(np.float32) * 0.1)
        batch["positions_3d"] = jnp.tile(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, 1))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    logits = models.forward(cfg, params, _batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_runs_and_is_finite(arch):
    from repro.train.train_step import make_train_step
    from repro.optim import adamw
    cfg = get_config(arch).reduced()
    shape = SHAPES["train_4k"]
    step, _ = make_train_step(cfg, shape)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params, adamw.AdamWConfig())
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    batch["labels"] = batch["tokens"]
    params, opt, metrics = step(params, opt, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "falcon-mamba-7b",
                                  "recurrentgemma-9b", "gemma3-12b",
                                  "olmoe-1b-7b"])
def test_decode_matches_forward(arch):
    """Stepping the decode cache token-by-token must reproduce the full
    forward logits — validates KV caches, ring buffers, and recurrent
    state updates in one shot."""
    import dataclasses
    # Disable MoE capacity drops: they are batch-size-dependent train-time
    # semantics, so prefill and decode would legitimately diverge.
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              moe_capacity_factor=16.0)
    params = models.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch(cfg, B, S, seed=3)
    ref_logits = np.asarray(
        models.forward(cfg, params, batch, remat=False))

    cache = models.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = models.decode_step(
            cfg, params, cache, batch["tokens"][:, t], jnp.int32(t))
        outs.append(np.asarray(lg))
    got = np.stack(outs, axis=1)    # [B, S, V]
    # bf16 compute: tiny attention-logit perturbations can flip borderline
    # top-k routing decisions in MoE archs, so a handful of tokens may
    # diverge legitimately — assert bulk agreement + top-1 match instead
    # of exact allclose.
    close = np.isclose(got, ref_logits, rtol=0.15, atol=0.15)
    assert close.mean() > 0.97, close.mean()
    top_ref = ref_logits.argmax(-1)
    top_got = got.argmax(-1)
    assert (top_ref == top_got).mean() > 0.9


def test_local_attention_matches_global_within_window():
    """A window >= seq makes local attention exactly global."""
    from repro.models.attention import chunked_attention, local_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)).astype(np.float32))
    full = chunked_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    loc = local_attention(q, k, v, window=64, q_block=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(loc),
                               rtol=2e-3, atol=2e-3)


def test_local_attention_window_effect():
    """Tokens beyond the window must not influence the output."""
    from repro.models.attention import local_attention
    rng = np.random.default_rng(1)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 8)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 8)).astype(np.float32))
    out1 = local_attention(q, k, v, window=8, q_block=16)
    # Perturb kv far outside any window of the last query block.
    k2 = k.at[:, :8].set(99.0)
    v2 = v.at[:, :8].set(99.0)
    out2 = local_attention(q, k2, v2, window=8, q_block=16)
    np.testing.assert_allclose(np.asarray(out1[:, 32:]),
                               np.asarray(out2[:, 32:]), rtol=1e-5)


def test_chunked_attention_matches_naive():
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(2, 32, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 32, 4, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 32, 4, 16)).astype(np.float32))
    got = chunked_attention(q, k, v, causal=True, q_block=8, kv_block=8)
    # naive reference
    logits = np.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(16)
    mask = np.tril(np.ones((32, 32), bool))
    logits = np.where(mask, logits, -1e30)
    p = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    ref = np.einsum("bhqk,bkhd->bqhd", np.asarray(p), v)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-3, atol=2e-3)


def test_mrope_sections_disagree():
    """M-RoPE with distinct h/w streams must differ from plain RoPE."""
    from repro.models.layers import apply_mrope, apply_rope
    x = jnp.ones((1, 8, 2, 16))
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    plain = apply_rope(x, pos, 10000.0)
    same = apply_mrope(x, jnp.stack([pos, pos, pos]), 10000.0)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(same),
                               rtol=1e-5)
    diff = apply_mrope(x, jnp.stack([pos, pos * 3, pos * 5]), 10000.0)
    assert not np.allclose(np.asarray(plain), np.asarray(diff))
