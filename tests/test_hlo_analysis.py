"""Loop-aware HLO cost model vs closed-form FLOP counts."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_flops import analyze_hlo
from repro.core.hlo_analysis import parse_collective_bytes


def _compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_plain_matmul():
    x = jnp.ones((64, 128))
    w = jnp.ones((128, 32))
    r = analyze_hlo(_compiled_text(lambda a, b: a @ b, x, w))
    assert r["flops"] == pytest.approx(2 * 64 * 128 * 32)


def test_scan_multiplies_flops():
    x = jnp.ones((8, 64))
    w = jnp.ones((64, 64))

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    r = analyze_hlo(_compiled_text(f, x, w))
    assert r["flops"] == pytest.approx(2 * 8 * 64 * 64 * 7)


def test_nested_scan():
    x = jnp.ones((8, 64))
    w = jnp.ones((64, 64))

    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    r = analyze_hlo(_compiled_text(g, x, w))
    assert r["flops"] == pytest.approx(2 * 8 * 64 * 64 * 15)


def test_batched_einsum():
    q = jnp.ones((2, 16, 4, 2, 8))
    k = jnp.ones((2, 32, 4, 8))
    r = analyze_hlo(_compiled_text(
        lambda q, k: jnp.einsum("bqhgd,bkhd->bhgqk", q, k), q, k))
    assert r["flops"] == pytest.approx(2 * 2 * 4 * 2 * 16 * 32 * 8)


def test_remat_scan_counts_recompute():
    """jax.checkpoint doubles forward FLOPs in the backward pass."""
    x = jnp.ones((8, 64))
    w = jnp.ones((64, 64))

    def loss(w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=6)
        return jnp.sum(out)

    r = analyze_hlo(_compiled_text(jax.grad(loss), w))
    fwd = 2 * 8 * 64 * 64 * 6
    # fwd + recompute-fwd + two backward matmuls per step ~ 4x fwd.
    assert r["flops"] >= 3 * fwd
    assert r["flops"] <= 5 * fwd


def test_bytes_positive_and_bounded():
    x = jnp.ones((256, 256))
    r = analyze_hlo(_compiled_text(lambda a: a + 1.0, x))
    assert r["bytes_accessed"] >= 2 * 256 * 256 * 4 * 0.9
    assert r["bytes_accessed"] <= 10 * 256 * 256 * 4


def test_collective_parse_on_hlo_snippet():
    text = """
ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %p), to_apply=%add
  ROOT %ag = f32[16,128]{1,0} all-gather(f32[2,128]{1,0} %ar), dimensions={0}
}
"""
    coll = parse_collective_bytes(text)
    assert coll["all-reduce"] == 16 * 128 * 4
    assert coll["all-gather"] == 2 * 128 * 4
    assert coll["total"] == coll["all-reduce"] + coll["all-gather"]
