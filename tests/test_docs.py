"""Docs freshness + link integrity + the sparse docstring gate.

These tests keep docs/ honest without a docs build: every module the
architecture guide names must exist, every intra-repo link must resolve
(same checker CI runs), and src/repro/sparse/ + src/repro/launch/ must
stay clean under the missing-docstring pydocstyle subset wired into ruff
(mirrored here in AST form so it is enforced even where ruff isn't
installed).
"""
import ast
import importlib.util
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ROOT / "docs"


def _load_check_links():
    spec = importlib.util.spec_from_file_location(
        "check_links", ROOT / "tools" / "check_links.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# --------------------------------------------------------------------- #
# Freshness: what architecture.md names must exist.
# --------------------------------------------------------------------- #

def test_docs_exist():
    for name in ("architecture.md", "roofline.md", "serving.md",
                 "serving_engine.md", "sharding.md"):
        assert (DOCS / name).is_file(), f"docs/{name} missing"


def test_architecture_modules_exist():
    """Every backticked repro.* dotted name in docs/architecture.md must
    resolve to a module or package under src/."""
    text = (DOCS / "architecture.md").read_text(encoding="utf-8")
    names = set(re.findall(r"`(repro(?:\.[a-z_0-9]+)+)`", text))
    assert len(names) >= 15, "architecture.md lost its module map"
    missing = []
    for name in sorted(names):
        rel = name.replace(".", "/")
        if not ((ROOT / "src" / f"{rel}.py").is_file()
                or (ROOT / "src" / rel / "__init__.py").is_file()):
            missing.append(name)
    assert not missing, f"architecture.md names missing modules: {missing}"


def test_architecture_file_paths_exist():
    """Backticked repo paths (benchmarks/..., tests/, .github/...) too."""
    text = "\n".join((DOCS / d).read_text(encoding="utf-8")
                     for d in ("architecture.md", "serving.md"))
    paths = set(re.findall(r"`([A-Za-z0-9_./-]+\.(?:py|yml|md))`", text))
    missing = [p for p in sorted(paths) if not (ROOT / p).exists()]
    assert not missing, f"docs name missing files: {missing}"


# --------------------------------------------------------------------- #
# Link integrity (the same checker the CI docs job runs).
# --------------------------------------------------------------------- #

def test_repo_markdown_links_resolve():
    cl = _load_check_links()
    broken = {}
    for f in cl.default_files(ROOT):
        b = cl.broken_links(f, ROOT)
        if b:
            broken[str(f.relative_to(ROOT))] = b
    assert not broken, f"broken intra-repo links: {broken}"


def test_link_checker_catches_breaks(tmp_path):
    cl = _load_check_links()
    (tmp_path / "a file.md").write_text("here")
    md = tmp_path / "x.md"
    md.write_text("# Frag\n"
                  "ok [a](https://example.com) [b](#frag)\n"
                  "bad [c](missing.md) img ![d](gone.png)\n"
                  "spaces ok [e](a file.md) [f](a%20file.md)\n"
                  "spaces bad [g](no such.md)\n")
    broken = cl.broken_links(md, tmp_path)
    assert [t for _, t in broken] == ["missing.md", "gone.png",
                                      "no such.md"]
    assert broken[0][0] == 3
    assert cl.main([str(md)]) == 1


# --------------------------------------------------------------------- #
# Anchor fragments: GitHub-style heading slugs (ROADMAP item).
# --------------------------------------------------------------------- #

def test_heading_slugs_match_github_rules():
    cl = _load_check_links()
    assert cl.slugify("Install") == "install"
    assert cl.slugify("The `plan`/`execute` API!") == "the-planexecute-api"
    assert cl.slugify("Ceilings: bandwidth & compute") == \
        "ceilings-bandwidth--compute"
    assert cl.slugify("A [link](x.md) in a heading") == \
        "a-link-in-a-heading"
    text = ("# Usage\n"
            "## Usage\n"          # duplicate -> -1 suffix
            "```\n# not a heading (code fence)\n```\n"
            "### Deep *emphasis* heading\n")
    anchors = cl.heading_anchors(text)
    assert anchors == {"usage", "usage-1", "deep-emphasis-heading"}


def test_anchor_fragments_are_verified(tmp_path):
    cl = _load_check_links()
    (tmp_path / "other.md").write_text("# Real Section\nbody\n")
    md = tmp_path / "x.md"
    md.write_text(
        "# My Title\n"
        "good [a](#my-title) [b](other.md#real-section)\n"
        "bad [c](#no-such-heading) [d](other.md#missing-anchor)\n"
        "external untouched [e](https://x.test/page#frag)\n"
        "non-md target fragment skipped [f](x.py#L10)\n")
    (tmp_path / "x.py").write_text("pass\n")
    broken = cl.broken_links(md, tmp_path)
    assert [t for _, t in broken] == ["#no-such-heading",
                                      "other.md#missing-anchor"]
    assert all(line == 3 for line, _ in broken)
    assert cl.main([str(md)]) == 1


# --------------------------------------------------------------------- #
# Docstring gate: the ruff D subset for src/repro/sparse/, in AST form.
# --------------------------------------------------------------------- #

def _public_defs_missing_docstrings(tree):
    missing = []

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}{child.name}"
                # D100-D104 scope: public names only; _private and dunder
                # defs (D105/D107 territory) are out of the selected set,
                # and so are function-local closures — recurse into class
                # bodies only, matching what ruff checks.
                public = not child.name.startswith("_")
                if public and ast.get_docstring(child) is None:
                    missing.append(qual)
                if isinstance(child, ast.ClassDef):
                    walk(child, f"{qual}.")
            else:
                walk(child, prefix)

    walk(tree, "")
    return missing


def test_sparse_package_docstring_clean():
    """Mirror of the ruff D100-D104 gate on src/repro/sparse/ and
    src/repro/launch/ (CI lints them with ruff; this keeps the gate
    active in ruff-less environments)."""
    failures = []
    for pkg in ("sparse", "launch"):
        for path in sorted((ROOT / "src" / "repro" / pkg).glob("*.py")):
            rel = f"{pkg}/{path.name}"
            tree = ast.parse(path.read_text(encoding="utf-8"))
            if ast.get_docstring(tree) is None:
                failures.append(f"{rel}: module docstring")
            failures += [f"{rel}: {q}"
                         for q in _public_defs_missing_docstrings(tree)]
    assert not failures, f"missing docstrings: {failures}"
