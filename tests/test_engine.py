"""Serving engine (repro.sparse.engine): coalescing, backpressure,
latency accounting.

The engine's single-threaded core (``submit`` / ``step`` / ``drain``)
is driven here with an injected fake clock, so the latency and goodput
arithmetic is pinned against hand-computed values instead of wall-clock
noise; the worker thread gets one end-to-end smoke test.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.core import blocked

N = 256


class FakeClock:
    """Injectable monotonic clock: advances only when the test says so."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def tick(self, dt):
        self.t += dt


def _mat(seed=3):
    return blocked(N, t=32, num_blocks=8, nnz_per_block=64, seed=seed)


def _b(d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))


def _engine(plan=None, **kw):
    kw.setdefault("clock", FakeClock())
    eng = sparse.ServingEngine(**kw)
    if plan is None:
        plan = sparse.plan(_mat(), sparse.BSpec(d=8, reuse=1024))
    eng.register("spmm", plan)
    return eng


# --------------------------------------------------------------------- #
# Numerics: coalesced batches must match per-request execution exactly.
# --------------------------------------------------------------------- #

def test_engine_matches_per_request_execution():
    """Acceptance: mixed-width coalesced serving == per-call spmm."""
    m = _mat()
    eng = _engine(plan=sparse.plan(m, sparse.BSpec(d=8, reuse=64)))
    bs = [_b(8, seed=0), _b(4, seed=1), _b(8, seed=2), _b(4, seed=3)]
    tickets = [eng.submit("spmm", b) for b in bs]
    assert eng.drain() == len(bs)
    for tk, b in zip(tickets, bs):
        got = tk.result(timeout=0)
        assert got.shape == (N, b.shape[1])
        ref = sparse.spmm(m, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # All four shared one launch: coalescing, not width, did the batching.
    assert eng.stats()["batches"] == 1
    assert eng.stats()["coalesced"] == 4


# --------------------------------------------------------------------- #
# Coalescing invariants: operator purity, budget, FIFO.
# --------------------------------------------------------------------- #

def test_batches_never_mix_operators_and_respect_budget():
    eng = _engine(max_batch_cols=16, double_buffer=False)
    eng.register("other", sparse.plan(_mat(seed=7),
                                      sparse.BSpec(d=8, reuse=64)))
    order = ["spmm", "other", "spmm", "other", "spmm", "spmm", "other"]
    for i, op in enumerate(order):
        eng.submit(op, _b(8, seed=i))
    assert eng.drain() == len(order)
    assert len(eng.batch_log) >= 4       # 16-col budget = 2 requests max
    for rec in eng.batch_log:
        assert sum(rec.widths) <= 16
        assert len(set(rec.request_ids)) == len(rec.request_ids)
    # FIFO within each operator: ids in admission order batch over batch.
    for op in ("spmm", "other"):
        ids = [rid for rec in eng.batch_log if rec.operator == op
               for rid in rec.request_ids]
        assert ids == sorted(ids)
    served_ids = sorted(rid for rec in eng.batch_log
                        for rid in rec.request_ids)
    assert served_ids == list(range(len(order)))


def test_head_of_queue_anchors_the_batch():
    """The queue head is always in the next batch — no operator starves."""
    eng = _engine(double_buffer=False)
    eng.register("other", sparse.plan(_mat(seed=7),
                                      sparse.BSpec(d=8, reuse=64)))
    eng.submit("other", _b(8, seed=0))
    for i in range(3):
        eng.submit("spmm", _b(8, seed=1 + i))
    eng.step()
    first = eng.batch_log[-1]
    assert first.operator == "other" and first.request_ids == (0,)
    eng.drain()
    assert eng.stats()["served"] == 4


def test_budget_floors_at_planned_width():
    """A planned-width request is always servable, whatever the cap."""
    eng = _engine(max_batch_cols=1)
    assert eng.budget_for("spmm") == 8
    t = eng.submit("spmm", _b(8))
    eng.drain()
    assert t.result(timeout=0).shape == (N, 8)


def test_coalesce_budget_properties():
    plan = sparse.plan(_mat(), sparse.BSpec(d=8, reuse=64))
    small = sparse.coalesce_budget(plan, stage_bytes=1)
    assert small == plan.spec.d          # floored at the planned width
    big = sparse.coalesce_budget(plan, stage_bytes=8 * 2 ** 20)
    assert big >= small and big % plan.spec.d == 0
    assert big == (8 * 2 ** 20 // (plan.n * 4)) // 8 * 8


# --------------------------------------------------------------------- #
# Backpressure: bounded queue, shed vs wait.
# --------------------------------------------------------------------- #

def test_shed_policy_rejects_at_admission():
    eng = _engine(max_queue=2, policy="shed")
    eng.submit("spmm", _b(8, seed=0))
    eng.submit("spmm", _b(8, seed=1))
    with pytest.raises(sparse.ShedError):
        eng.submit("spmm", _b(8, seed=2))
    s = eng.stats()
    assert s["admitted"] == 2 and s["shed"] == 1
    assert eng.drain() == 2              # admitted requests still serve


def test_wait_policy_timeout_sheds():
    eng = _engine(max_queue=1, policy="wait")
    eng.submit("spmm", _b(8, seed=0))
    with pytest.raises(sparse.ShedError):
        eng.submit("spmm", _b(8, seed=1), timeout=0.01)
    assert eng.stats()["shed"] == 1


def test_bad_submissions_raise():
    eng = _engine()
    with pytest.raises(KeyError):
        eng.submit("nope", _b(8))
    with pytest.raises(ValueError):
        eng.submit("spmm", jnp.zeros((N + 1, 8), jnp.float32))
    with pytest.raises(ValueError):
        sparse.ServingEngine(policy="drop")
    with pytest.raises(ValueError):
        sparse.ServingEngine(max_queue=0)


# --------------------------------------------------------------------- #
# Latency accounting: hand-computed percentiles and goodput.
# --------------------------------------------------------------------- #

def test_latency_and_goodput_match_hand_computed_values():
    clock = FakeClock()
    eng = _engine(clock=clock, double_buffer=False)
    # r0 at t=0 with a deadline it will miss; r1 at t=0.5; batch at t=1.
    t0 = eng.submit("spmm", _b(8, seed=0), deadline_s=0.4)
    clock.tick(0.5)
    t1 = eng.submit("spmm", _b(8, seed=1))
    clock.tick(0.5)
    assert eng.step() == 2
    assert t0.latency_s == pytest.approx(1.0)
    assert t1.latency_s == pytest.approx(0.5)
    assert t0.met_deadline is False and t1.met_deadline is None
    s = eng.stats()
    lats_us = [0.5e6, 1.0e6]
    assert s["p50_us"] == pytest.approx(np.percentile(lats_us, 50))
    assert s["p99_us"] == pytest.approx(np.percentile(lats_us, 99))
    # Goodput: 1 deadline-meeting completion over the 1s span.
    assert s["deadline_miss"] == 1
    assert s["goodput_rps"] == pytest.approx(1.0)
    rec = eng.batch_log[-1]
    assert rec.queued_s == pytest.approx(1.0)    # oldest member waited 1s
    assert rec.exec_s == pytest.approx(0.0)
    assert t0.batch_seq == t1.batch_seq == 0


def test_reset_stats_clears_accounting_only():
    eng = _engine()
    eng.submit("spmm", _b(8))
    eng.drain()
    assert eng.stats()["served"] == 1
    eng.reset_stats()
    s = eng.stats()
    assert s["served"] == s["batches"] == 0
    assert s["p50_us"] == s["goodput_rps"] == 0.0
    t = eng.submit("spmm", _b(8))        # plans + id numbering survive
    eng.drain()
    assert t.id == 1 and eng.stats()["served"] == 1


# --------------------------------------------------------------------- #
# Warm-up, re-plan swap, summary.
# --------------------------------------------------------------------- #

def test_warmup_primes_size_classes_without_skewing_reuse():
    eng = _engine()
    warmed = eng.warmup("spmm")
    assert warmed >= 1
    assert eng.plan_for("spmm").executed == 0
    assert eng.stats()["served"] == 0


def test_auto_replan_swaps_plan_atomically():
    plan = sparse.plan(_mat(), sparse.BSpec(d=8, reuse=1))
    eng = _engine(plan=plan, max_batch_cols=8, double_buffer=False,
                  auto_replan=True)
    for i in range(6):                   # single-request batches drift
        eng.submit("spmm", _b(8, seed=i))
    eng.drain()
    assert eng.stats()["replans"] >= 1
    fresh = eng.plan_for("spmm")
    assert fresh is not plan
    assert fresh.spec.reuse >= plan.spec.reuse
    t = eng.submit("spmm", _b(8))        # fresh plan serves
    eng.drain()
    assert t.result(timeout=0).shape == (N, 8)


def test_summary_renders_batch_log():
    eng = _engine()
    eng.submit("spmm", _b(8, seed=0))
    eng.submit("spmm", _b(4, seed=1))
    eng.drain()
    text = eng.summary()
    assert "admitted=2" in text and "batch " in text
    assert "widths=[8, 4]" in text


# --------------------------------------------------------------------- #
# Worker thread: end-to-end smoke (real clock, real threads).
# --------------------------------------------------------------------- #

def test_worker_thread_serves_submissions():
    import time
    eng = sparse.ServingEngine(max_queue=4, policy="wait")
    eng.register("spmm", sparse.plan(_mat(), sparse.BSpec(d=8, reuse=64)))
    eng.warmup("spmm")
    eng.start()
    eng.start()                          # idempotent
    try:
        bs = [_b(8, seed=s) for s in range(8)]   # > max_queue: wait kicks in
        tickets = [eng.submit("spmm", b) for b in bs]
        outs = [t.result(timeout=120.0) for t in tickets]
    finally:
        eng.stop(timeout=120.0)
    for out, b in zip(outs, bs):
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(sparse.spmm(_mat(), b)),
            rtol=1e-5, atol=1e-5)
    assert eng.stats()["served"] == 8 and eng.pending() == 0
