"""Pipeline parallelism: multi-device equivalence vs sequential stack."""
import subprocess
import sys

import pytest

# Whole-module integration tests: excluded from tier-1 (run nightly / -m slow).
pytestmark = pytest.mark.slow

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline import pipeline_apply, split_stages

S, L, D, N_MICRO, MB = 4, 8, 16, 6, 4
mesh = jax.make_mesh((S,), ("stage",))
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (L, D, D)) * (1.0 / np.sqrt(D))

def block_fn(params, x):
    # params: [L/S, D, D]; apply the stage's layers sequentially.
    def body(h, w):
        return jnp.tanh(h @ w), None
    out, _ = jax.lax.scan(body, x, params)
    return out

x = jax.random.normal(jax.random.PRNGKey(1), (N_MICRO, MB, D))
stage_params = split_stages(Ws, S)
got = pipeline_apply(block_fn, stage_params, x, mesh=mesh)

# Sequential reference: all L layers over each microbatch.
def seq(x1):
    h = x1
    for i in range(L):
        h = jnp.tanh(h @ Ws[i])
    return h
ref = jax.vmap(seq)(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)

# Gradients flow through the pipeline schedule (backward pipeline).
def loss(sp):
    return jnp.sum(pipeline_apply(block_fn, sp, x, mesh=mesh) ** 2)
g = jax.grad(loss)(stage_params)
def loss_ref(w):
    h = x
    def seq2(x1):
        h = x1
        for i in range(L):
            h = jnp.tanh(h @ w[i])
        return h
    return jnp.sum(jax.vmap(seq2)(x) ** 2)
g_ref = split_stages(jax.grad(loss_ref)(Ws), S)
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                           rtol=1e-4, atol=1e-4)
print("PIPELINE-OK")
"""


def test_pipeline_multi_device_equivalence():
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "PIPELINE-OK" in r.stdout, (r.stdout[-1000:], r.stderr[-2000:])
