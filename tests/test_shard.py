"""Sharded SpMM tier: splitter properties, strategy cost model, and
sharded-vs-single-device equivalence (in-process on the visible devices;
an 8-virtual-device subprocess covers every (format, strategy) pair)."""

import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # declared dev dep; CI installs the real one
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.core import patterns
from repro.core.hardware import HOST_CPU, TPU_V5E
from repro.core.roofline import ShardRoofline, collective_time
from repro.core.sparsity_models import TrafficBreakdown, shard_traffic
from repro.launch.mesh import make_shard_mesh
from repro import sparse

N, D_COL = 256, 32


def _mats():
    return {
        "banded": patterns.banded(N, bandwidth=4, seed=1),
        "blocked": patterns.block_diagonal(N, t=64, seed=2),
        "random": patterns.erdos_renyi(N, avg_degree=8, seed=3),
        "scale_free": patterns.scale_free(N, avg_degree=6, seed=4),
    }


# --------------------------------------------------------------------------
# nnz-balanced prefix-sum splitter
# --------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       shards=st.integers(min_value=1, max_value=16),
       skew=st.floats(min_value=0.0, max_value=3.0))
@settings(max_examples=60, deadline=None)
def test_splitter_balance_property(seed, shards, skew):
    """Max shard weight <= mean + heaviest item (the splitter's bound)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(shards, 512))
    weights = np.floor(rng.lognormal(0.0, skew, size=n)).astype(np.int64)
    bounds = sparse.nnz_balanced_splits(weights, shards)
    assert bounds[0] == 0 and bounds[-1] == n
    assert np.all(np.diff(bounds) >= 0)
    per_shard = np.array([weights[s:e].sum()
                          for s, e in zip(bounds[:-1], bounds[1:])])
    assert per_shard.sum() == weights.sum()
    total = weights.sum()
    if total > 0:
        mean = total / shards
        # epsilon is the heaviest single item relative to the mean: a cut
        # can miss its target by at most one item's weight.
        assert per_shard.max() <= mean + weights.max() + 1e-9


def test_splitter_alignment():
    """align=t cuts only on block edges (BCSR shards keep blocks whole)."""
    rng = np.random.default_rng(0)
    weights = rng.integers(0, 50, size=256)
    bounds = sparse.nnz_balanced_splits(weights, 8, align=32)
    assert np.all(bounds % 32 == 0)
    # Aligned bound: off by at most one aligned group of items.
    per_shard = np.array([weights[s:e].sum()
                          for s, e in zip(bounds[:-1], bounds[1:])])
    groups = weights.reshape(-1, 32).sum(axis=1)
    assert per_shard.max() <= weights.sum() / 8 + groups.max() + 1e-9


def test_splitter_validation():
    with pytest.raises(ValueError, match="num_shards"):
        sparse.nnz_balanced_splits([1, 2], 0)
    with pytest.raises(ValueError, match="align"):
        sparse.nnz_balanced_splits([1, 2], 1, align=0)
    with pytest.raises(ValueError, match="divisible"):
        sparse.nnz_balanced_splits([1, 2, 3], 1, align=2)
    # Degenerate but legal: one shard, and all-zero weights.
    assert list(sparse.nnz_balanced_splits([5, 5], 1)) == [0, 2]
    bounds = sparse.nnz_balanced_splits(np.zeros(8, np.int64), 4)
    assert bounds[0] == 0 and bounds[-1] == 8


# --------------------------------------------------------------------------
# Communication-aware roofline pieces
# --------------------------------------------------------------------------

def test_collective_time_model():
    """Zero on one device; bandwidth + log2(D) latency terms otherwise."""
    assert collective_time(1e9, HOST_CPU, 1) == 0.0
    t8 = collective_time(8e6, TPU_V5E, 8, collectives=2)
    expected = 8e6 / TPU_V5E.collective_bandwidth + \
        2 * TPU_V5E.collective_latency_s * 3          # ceil(log2 8) = 3
    assert t8 == pytest.approx(expected)
    # More devices -> more latency hops for the same bytes.
    assert collective_time(8e6, TPU_V5E, 64) > collective_time(
        8e6, TPU_V5E, 8)
    # HOST_CPU has no ICI: collectives fall back to memory bandwidth.
    assert HOST_CPU.collective_bandwidth == HOST_CPU.hbm_bandwidth
    assert TPU_V5E.collective_bandwidth == TPU_V5E.ici_bytes_per_s


def test_shard_traffic_scaling():
    """FLOPs/A scale with nnz share, C with rows share, B overridable."""
    tb = TrafficBreakdown(flops=1000.0, bytes_a=400.0, bytes_b=200.0,
                          bytes_c=100.0, model="random")
    s = shard_traffic(tb, nnz_fraction=0.25, rows_fraction=0.5)
    assert s.flops == 250.0 and s.bytes_a == 100.0
    assert s.bytes_b == 50.0 and s.bytes_c == 50.0
    assert s.model.endswith("+shard")
    band = shard_traffic(tb, nnz_fraction=0.25, rows_fraction=1.0,
                         bytes_b=200.0)
    assert band.bytes_b == 200.0 and band.bytes_c == 100.0


def test_shard_roofline_dominance():
    fast = ShardRoofline(strategy="replicate", devices=8, shard_ai=1.0,
                         critical_flops=1e6, total_flops=8e6,
                         compute_s=1e-3, collective_s=1e-4,
                         collective_bytes=1e6)
    assert fast.dominant == "compute"
    assert fast.total_s == pytest.approx(1.1e-3)
    assert fast.predicted_flops_per_s == pytest.approx(8e6 / 1.1e-3)
    slow = ShardRoofline(strategy="all_gather", devices=8, shard_ai=1.0,
                         critical_flops=1e6, total_flops=8e6,
                         compute_s=1e-4, collective_s=1e-3,
                         collective_bytes=1e6)
    assert slow.dominant == "collective"


# --------------------------------------------------------------------------
# ShardedPlan on the visible devices (1 locally; 8 under the CI lane's
# XLA_FLAGS=--xla_force_host_platform_device_count=8)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh():
    return make_shard_mesh()


def _b(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))


@pytest.mark.parametrize("name", ["banded", "blocked", "random",
                                  "scale_free"])
def test_sharded_equivalence_all_strategies(name, mesh):
    """Every eligible (format, B-strategy) pair matches the dense product."""
    m = _mats()[name]
    b = _b(m.n, D_COL, seed=7)
    ref = np.asarray(sparse.coo_to_dense(m)) @ np.asarray(b)
    for strat in ("auto",) + sparse.B_STRATEGIES:
        try:
            p = sparse.plan(m, sparse.BSpec(d=D_COL), mesh=mesh,
                            b_strategy=strat)
        except ValueError:
            continue                      # ineligible (dia + all_gather)
        out = np.asarray(p.execute(b))
        np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-4)
        assert sum(p.shard_nnz) == m.nnz


def test_sharded_plan_summary_and_stats(mesh):
    """summary() records every strategy's predicted cost + skip reasons."""
    p = sparse.plan(_mats()["banded"], sparse.BSpec(d=D_COL), mesh=mesh)
    assert p.chosen == "dia"
    s = p.summary()
    assert "ShardedPlan(" in s and p.b_strategy in s
    for strat in sparse.B_STRATEGIES:
        assert strat in s
    assert "SKIP:" in s                   # dia + all_gather skip reason
    evals = {e.strategy: e for e in p.strategy_evals}
    assert not evals["all_gather"].eligible
    assert evals["all_gather"].predicted_gflops is None
    for strat in ("replicate", "reduce_scatter"):
        ev = evals[strat]
        assert ev.eligible and ev.predicted_gflops > 0
        assert ev.roofline.devices == p.num_shards
        assert ev.roofline.dominant in ("compute", "collective")
    st_ = p.stats()
    assert st_["devices"] == p.num_shards
    assert st_["b_strategy"] == p.b_strategy
    assert len(st_["shard_nnz"]) == p.num_shards


def test_sharded_auto_picks_best_predicted(mesh):
    """b_strategy="auto" selects the max predicted-GFLOP/s eligible eval."""
    p = sparse.plan(_mats()["random"], sparse.BSpec(d=D_COL), mesh=mesh)
    best = max((e for e in p.strategy_evals if e.eligible),
               key=lambda e: e.predicted_gflops)
    assert p.b_strategy == best.strategy


def test_sharded_plan_errors(mesh):
    m = _mats()["banded"]
    with pytest.raises(ValueError, match="unknown b_strategy"):
        sparse.plan(m, sparse.BSpec(d=D_COL), mesh=mesh,
                    b_strategy="broadcast")
    with pytest.raises(ValueError, match="ineligible"):
        sparse.plan(m, sparse.BSpec(d=D_COL), mesh=mesh,
                    b_strategy="all_gather")     # dia band shards
    with pytest.raises(ValueError, match="requires a mesh"):
        sparse.plan(m, sparse.BSpec(d=D_COL), b_strategy="replicate")


def test_sharded_stream_interfaces(mesh):
    """execute_many / execute_wide / replan compose with the sharded tier."""
    m = _mats()["random"]
    p = sparse.plan(m, sparse.BSpec(d=D_COL, reuse=4), mesh=mesh)
    dense = np.asarray(sparse.coo_to_dense(m))
    bs = [_b(m.n, D_COL, seed=s) for s in (1, 2)]
    outs = np.asarray(p.execute_many(bs))
    for i, b in enumerate(bs):
        np.testing.assert_allclose(outs[i], dense @ np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
    wide = _b(m.n, 3 * D_COL + 5, seed=3)
    np.testing.assert_allclose(np.asarray(p.execute_wide(wide)),
                               dense @ np.asarray(wide),
                               rtol=5e-4, atol=5e-4)
    p2 = p.replan(128)
    assert isinstance(p2, sparse.ShardedPlan)
    assert p2.num_shards == p.num_shards
    np.testing.assert_allclose(np.asarray(p2.execute(bs[0])),
                               dense @ np.asarray(bs[0]),
                               rtol=5e-4, atol=5e-4)


# --------------------------------------------------------------------------
# 8-virtual-device equivalence for every (format, strategy) pair
# --------------------------------------------------------------------------

_EIGHT_DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax.numpy as jnp
from repro.core import patterns
from repro.launch.mesh import make_shard_mesh
from repro import sparse

mesh = make_shard_mesh(8)
N, d = 256, 32
mats = {
    "banded": patterns.banded(N, bandwidth=4, seed=1),
    "blocked": patterns.block_diagonal(N, t=64, seed=2),
    "random": patterns.erdos_renyi(N, avg_degree=8, seed=3),
    "scale_free": patterns.scale_free(N, avg_degree=6, seed=4),
}
b = jnp.asarray(np.random.default_rng(0).standard_normal(
    (N, d)).astype(np.float32))
pairs = 0
for name, m in mats.items():
    ref = np.asarray(sparse.coo_to_dense(m)) @ np.asarray(b)
    for strat in sparse.B_STRATEGIES:
        try:
            p = sparse.plan(m, sparse.BSpec(d=d), mesh=mesh,
                            b_strategy=strat)
        except ValueError:
            assert name == "banded" and strat == "all_gather"
            continue
        assert p.num_shards == 8
        out = np.asarray(p.execute(b))
        np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-4)
        pairs += 1
assert pairs == 11, pairs   # 4 structures x 3 strategies - dia/all_gather

# PR 8's scale-free tier, forced through the sharded path: the gather
# family executes via the per-shard CSR packing, so every B-strategy
# must be eligible and match dense on 8 devices.
sf = mats["scale_free"]
ref = np.asarray(sparse.coo_to_dense(sf)) @ np.asarray(b)
for fmt_name in ("binned", "rowsplit", "ell_coo"):
    for strat in sparse.B_STRATEGIES:
        p = sparse.plan(sf, sparse.BSpec(d=d), mesh=mesh,
                        strategy=fmt_name, b_strategy=strat)
        assert p.num_shards == 8
        assert p.dispatch.chosen == fmt_name
        # Audit contract: every ineligible strategy eval says why.
        for e in p.strategy_evals:
            assert e.eligible or e.skip_reason, e.strategy
        out = np.asarray(p.execute(b))
        np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-4)
        pairs += 1
assert pairs == 20, pairs   # 11 + 3 new formats x 3 strategies
print("SHARD-8DEV-OK")
"""


def test_sharded_equivalence_eight_devices():
    """All (format, strategy) pairs match dense on an 8-device mesh."""
    # JAX_PLATFORMS=cpu: without it jax probes this container's libtpu
    # for minutes before falling back; the script forces virtual host
    # devices regardless.
    r = subprocess.run([sys.executable, "-c", _EIGHT_DEV],
                       capture_output=True, text=True, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "SHARD-8DEV-OK" in r.stdout, r.stderr[-2000:]
