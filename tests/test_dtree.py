"""Decision-tree dispatch fallback: CART, serialization, persistence.

The tree is the SpChar-style learned component; these tests pin the
feature schema (including the inf-alpha cap), the fit/predict/path
contract, the lossless JSON round-trip the fingerprint-based plan
caching depends on, and the store's refusal of stale payloads —
mirroring the CalibrationStore's registry-version staleness gate.
"""
import json

import numpy as np
import pytest

from repro.core import patterns
from repro.core.classify import classify
from repro.data.dtree import (ALPHA_CAP, FEATURES, DecisionTree,
                              DispatchTreeStore, features_from_report)
from repro.kernels import registry


def _toy_data():
    """Linearly separable two-class set over the real feature schema."""
    mats = [patterns.erdos_renyi(256, 8, seed=s) for s in range(4)] + \
           [patterns.banded(256, 3, seed=s) for s in range(4)]
    x = np.stack([features_from_report(classify(m), 32) for m in mats])
    y = ["csr"] * 4 + ["dia"] * 4
    return x, y


def test_features_match_schema():
    m = patterns.banded(128, 1, seed=0)          # flat degrees: alpha=inf
    report = classify(m)
    assert report.stats["alpha_hill"] == float("inf")
    x = features_from_report(report, 64)
    assert x.shape == (len(FEATURES),)
    assert np.all(np.isfinite(x))                # inf capped for splits
    assert x[FEATURES.index("alpha_hill")] == ALPHA_CAP
    assert x[FEATURES.index("d")] == 64.0
    # d is part of the decision: two widths give distinct vectors.
    assert not np.array_equal(x, features_from_report(report, 128))


def test_fit_predict_and_path():
    x, y = _toy_data()
    tree = DecisionTree(max_depth=3, min_leaf=1).fit(x, y)
    for xi, yi in zip(x, y):
        assert tree.predict(xi) == yi
        path = tree.decision_path(xi)
        assert path[-1].startswith(f"leaf:{yi}(")
        assert all(("<=" in step) or (">" in step) for step in path[:-1])


def test_fit_rejects_bad_shapes():
    with pytest.raises(ValueError, match="non-empty"):
        DecisionTree().fit(np.zeros((0, len(FEATURES))), [])
    with pytest.raises(ValueError, match="features"):
        DecisionTree().fit(np.zeros((2, 3)), ["a", "b"])
    with pytest.raises(ValueError, match="not fitted"):
        DecisionTree().predict(np.zeros(len(FEATURES)))


def test_json_round_trip_preserves_predictions():
    x, y = _toy_data()
    tree = DecisionTree(max_depth=3, min_leaf=1).fit(x, y)
    clone = DecisionTree.from_json(
        json.loads(json.dumps(tree.to_json())))
    for xi in x:
        assert clone.predict(xi) == tree.predict(xi)
        assert clone.decision_path(xi) == tree.decision_path(xi)
    assert clone.fingerprint() == tree.fingerprint()
    other = DecisionTree(max_depth=1, min_leaf=1).fit(x, y)
    assert other.fingerprint() != tree.fingerprint()


def test_store_round_trip(tmp_path):
    x, y = _toy_data()
    tree = DecisionTree(max_depth=2, min_leaf=1).fit(x, y)
    store = DispatchTreeStore(tmp_path)
    assert store.load("jax") is None             # absent: analytic-only
    path = store.save(tree, "jax", meta={"rows": len(y)})
    assert path.name == "dispatch_tree-jax.json"
    loaded = store.load("jax")
    assert loaded is not None
    assert loaded.fingerprint() == tree.fingerprint()
    assert store.load("pallas") is None          # per-backend files


def test_store_refuses_stale_payloads(tmp_path):
    x, y = _toy_data()
    tree = DecisionTree(max_depth=2, min_leaf=1).fit(x, y)
    store = DispatchTreeStore(tmp_path)
    store.save(tree, "jax")
    path = store.path_for("jax")

    payload = json.loads(path.read_text())
    payload["registry_version"] = registry.REGISTRY_VERSION - 1
    path.write_text(json.dumps(payload))
    assert store.load("jax") is None             # predates the registry

    payload = json.loads(path.read_text())
    payload["registry_version"] = registry.REGISTRY_VERSION
    payload["tree"]["features"] = ["bogus"]
    path.write_text(json.dumps(payload))
    assert store.load("jax") is None             # feature-schema drift

    path.write_text("{not json")
    assert store.load("jax") is None             # corrupt file, no raise


def test_store_honors_calibration_dir_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIBRATION_DIR", str(tmp_path))
    x, y = _toy_data()
    tree = DecisionTree(max_depth=1, min_leaf=1).fit(x, y)
    DispatchTreeStore().save(tree, "jax")
    assert (tmp_path / "dispatch_tree-jax.json").is_file()
    assert DispatchTreeStore().load("jax") is not None
