"""On-host ceiling calibration: fit, store round-trip, dispatcher pickup."""

import dataclasses
import json

import numpy as np
import pytest

from repro import sparse
from repro.core import banded, blocked, erdos_renyi
from repro.core.calibrate import (
    Calibration, CalibrationStore, FormatCalibration, calibrate,
    fit_ceiling,
)
from repro.core.hardware import HOST_CPU, TPU_V5E
from repro.core.roofline import ComputeCeiling

N = 512


# --------------------------------------------------------------------- #
# The fit.
# --------------------------------------------------------------------- #

def test_fit_ceiling_recovers_synthetic_params():
    d = np.array([2, 8, 32, 128, 512])
    g_inf, d_half = 80.0, 24.0
    g = g_inf * d / (d + d_half)
    fit_g, fit_dh = fit_ceiling(d, g)
    assert fit_g == pytest.approx(g_inf, rel=1e-6)
    assert fit_dh == pytest.approx(d_half, rel=1e-6)


def test_fit_ceiling_degenerate_sweeps():
    # Flat throughput: no saturation info -> asymptote = max, d_half = 0.
    g, dh = fit_ceiling([4, 16, 64], [5.0, 5.0, 5.0])
    assert g == pytest.approx(5.0) and dh == pytest.approx(0.0, abs=1e-9)
    # Decreasing with d (anti-model): fall back, don't extrapolate.
    g, dh = fit_ceiling([4, 16, 64], [10.0, 6.0, 2.0])
    assert g == pytest.approx(10.0) and dh == 0.0
    # Non-positive measurement: degenerate fallback, never a crash.
    g, dh = fit_ceiling([4, 16], [0.0, 1.0])
    assert g > 0 and dh == 0.0
    with pytest.raises(ValueError):
        fit_ceiling([4], [1.0])


def test_compute_ceiling_shape():
    c = ComputeCeiling(peak_fraction=0.5, d_half=16.0, source="calibrated")
    peak = 100e9
    # Half-saturation at d = d_half, asymptote at large d.
    assert c.attainable(peak, 1.0, 16) == pytest.approx(0.25 * peak)
    assert c.attainable(peak, 1.0, 10_000_000) == pytest.approx(
        0.5 * peak, rel=1e-3)
    assert c.attainable(peak, 0.5, 10_000_000) == pytest.approx(
        0.25 * peak, rel=1e-3)


# --------------------------------------------------------------------- #
# Store round-trip + fingerprint gating.
# --------------------------------------------------------------------- #

def _fake_calibration(hw, fmt="csr"):
    return Calibration(
        hardware=hw.name, fingerprint=hw.fingerprint(), backend="jax",
        entries=(FormatCalibration(
            format=fmt, backend="jax", peak_fraction=0.123, d_half=7.0,
            sustained_gflops=1.5, useful_fraction=1.0,
            measured={4: 0.5, 64: 1.4}),))


def test_store_round_trip(tmp_path):
    store = CalibrationStore(root=tmp_path)
    cal = _fake_calibration(HOST_CPU)
    path = store.save(cal)
    assert path == store.path_for(HOST_CPU) and path.is_file()
    loaded = store.load(HOST_CPU)
    assert loaded is not None
    assert loaded.efficiency() == {"csr": (0.123, 7.0)}
    assert loaded.entries[0].measured == {4: 0.5, 64: 1.4}
    assert loaded.fingerprint == HOST_CPU.fingerprint()


def test_store_fingerprint_mismatch_falls_back(tmp_path):
    store = CalibrationStore(root=tmp_path)
    store.save(_fake_calibration(HOST_CPU))
    # Same name, different compute identity: the stored calibration must
    # not be applied.
    changed = dataclasses.replace(HOST_CPU, peak_flops=HOST_CPU.peak_flops * 2)
    assert changed.fingerprint() != HOST_CPU.fingerprint()
    assert store.load(changed) is None
    # Bandwidth substitution (the STREAM-measured beta) must NOT
    # invalidate a calibration: ceilings are compute-side.
    rebw = dataclasses.replace(HOST_CPU, hbm_bandwidth=123e9)
    assert rebw.fingerprint() == HOST_CPU.fingerprint()
    assert store.load(rebw) is not None


def test_store_keys_by_backend(tmp_path):
    """jax and pallas calibrations for one host must not cross-answer:
    different files, and load() rejects a backend mismatch."""
    store = CalibrationStore(root=tmp_path)
    jax_cal = _fake_calibration(HOST_CPU)
    pallas_cal = dataclasses.replace(jax_cal, backend="pallas")
    p1 = store.save(jax_cal)
    p2 = store.save(pallas_cal)
    assert p1 != p2                              # no silent overwrite
    assert store.load(HOST_CPU, "jax").backend == "jax"
    assert store.load(HOST_CPU, "pallas").backend == "pallas"
    # A dispatcher resolving to jax must not see pallas-only ceilings.
    p2.unlink()
    p1.rename(store.path_for(HOST_CPU, "pallas"))   # mislabeled file
    assert store.load(HOST_CPU, "pallas") is None   # backend field wins


def test_dispatcher_ignores_other_backend_calibration(tmp_path):
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=8e9)
    store = CalibrationStore(root=tmp_path)
    store.save(dataclasses.replace(_fake_calibration(hw),
                                   backend="pallas"))
    # Off-TPU the dispatcher resolves backend="jax": the pallas-fitted
    # ceilings must not be applied.
    disp = sparse.Dispatcher(hardware=hw, calibration=store)
    plan = disp.plan(erdos_renyi(N, 8, seed=1), 8)
    assert set(plan.ceiling_sources.values()) == {"default"}
    disp_p = sparse.Dispatcher(hardware=hw, backend="pallas",
                               calibration=store)
    assert disp_p.plan(erdos_renyi(N, 8, seed=2), 8) \
        .ceiling_sources["csr"] == "calibrated"


def test_store_tolerates_absent_and_corrupt_files(tmp_path):
    store = CalibrationStore(root=tmp_path / "nowhere")
    assert store.load(HOST_CPU) is None
    store2 = CalibrationStore(root=tmp_path)
    store2.root.mkdir(exist_ok=True)
    store2.path_for(HOST_CPU).write_text("{not json")
    assert store2.load(HOST_CPU) is None


def test_fingerprint_distinguishes_specs():
    assert HOST_CPU.fingerprint() != TPU_V5E.fingerprint()
    assert len(HOST_CPU.fingerprint()) == 12
    assert HOST_CPU.fingerprint() == HOST_CPU.fingerprint()


# --------------------------------------------------------------------- #
# Dispatcher pickup: calibrated vs default vs override provenance.
# --------------------------------------------------------------------- #

def _mats():
    return {
        "random": erdos_renyi(N, 8, seed=1),
        "banded": banded(N, 3, fill=0.9, seed=2),
        "fem": blocked(N, t=32, num_blocks=N // 16, nnz_per_block=320,
                       seed=3),
    }


def test_dispatcher_uses_calibrated_ceilings(tmp_path):
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=8e9)
    store = CalibrationStore(root=tmp_path)
    cal = Calibration(
        hardware=hw.name, fingerprint=hw.fingerprint(), backend="jax",
        entries=tuple(FormatCalibration(
            format=f, backend="jax", peak_fraction=0.2, d_half=10.0,
            sustained_gflops=1.0, useful_fraction=1.0, measured={})
            for f in sparse.FORMATS))
    store.save(cal)
    m = _mats()["fem"]
    d = 16
    disp = sparse.Dispatcher(hardware=hw, calibration=store)
    plan = disp.plan(m, d)
    assert set(plan.ceiling_sources.values()) == {"calibrated"}
    # The prediction must equal the model evaluated with the calibrated
    # pair: min(beta * AI, peak * 0.2 * useful * d / (d + 10)).
    cand = plan.candidate("csr")
    expect = min(hw.hbm_bandwidth * cand.ai,
                 hw.peak_flops * 0.2 * cand.useful_fraction * d / (d + 10.0))
    assert cand.predicted_gflops == pytest.approx(expect / 1e9, rel=1e-6)
    # Same matrix, no calibration on disk -> defaults, different numbers.
    disp_def = sparse.Dispatcher(
        hardware=hw, calibration=CalibrationStore(root=tmp_path / "empty"))
    plan_def = disp_def.plan(m, d)
    assert set(plan_def.ceiling_sources.values()) == {"default"}
    assert plan_def.candidate("csr").predicted_gflops != \
        cand.predicted_gflops


def test_override_beats_calibration_and_refresh(tmp_path):
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=8e9)
    store = CalibrationStore(root=tmp_path)
    m = _mats()["random"]
    disp = sparse.Dispatcher(hardware=hw, calibration=store,
                             efficiency={"csr": (0.5, 1.0)})
    plan = disp.plan(m, 8)
    assert plan.ceiling_sources["csr"] == "override"
    assert plan.ceiling_sources["ell"] == "default"   # nothing stored yet
    store.save(_fake_calibration(hw, fmt="ell"))
    disp.refresh_calibration()                         # drop caches
    plan2 = disp.plan(m, 8)
    assert plan2.ceiling_sources["ell"] == "calibrated"
    assert plan2.ceiling_sources["csr"] == "override"  # still pinned
    # Only csr rows are pinned (one summary line per evaluated precision).
    n_csr_rows = sum(1 for c in plan2.candidates if c.format == "csr")
    assert plan2.summary().count("[override]") == n_csr_rows


def test_calibration_disabled_sentinel(tmp_path):
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=8e9)
    store = CalibrationStore(root=tmp_path)
    store.save(_fake_calibration(hw))
    disp = sparse.Dispatcher(hardware=hw, calibration=False)
    assert set(disp.plan(_mats()["random"], 8)
               .ceiling_sources.values()) == {"default"}


# --------------------------------------------------------------------- #
# The measured sweep end-to-end (tiny scale).
# --------------------------------------------------------------------- #

def test_calibrate_end_to_end(tmp_path):
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=8e9)
    store = CalibrationStore(root=tmp_path)
    cal = calibrate(hw, backend="jax", scale=7, repeats=1,
                    d_values=(4, 16, 64), bcsr_block=16, store=store)
    assert {e.format for e in cal.entries} == set(sparse.FORMATS)
    for e in cal.entries:
        assert 1e-5 <= e.peak_fraction <= 1.0
        assert 0.0 <= e.d_half <= 4096.0
        assert set(e.measured) == {4, 16, 64}
        assert all(v > 0 for v in e.measured.values())
    # Persisted and valid JSON keyed by the spec fingerprint.
    payload = json.loads(store.path_for(hw).read_text())
    assert payload["fingerprint"] == hw.fingerprint()
    # A dispatcher on the same hardware now predicts from it.
    disp = sparse.Dispatcher(hardware=hw, calibration=store)
    plan = disp.plan(_mats()["banded"], 16)
    assert set(plan.ceiling_sources.values()) == {"calibrated"}
    with pytest.raises(ValueError):
        calibrate(hw, formats=["nope"], scale=6)


# --------------------------------------------------------------------- #
# Staleness nudge: plan summaries flag calibrations that no longer
# describe what is about to run.
# --------------------------------------------------------------------- #

def test_staleness_note_version_and_fingerprint(tmp_path):
    from repro.kernels import registry
    store = CalibrationStore(root=tmp_path)
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=8e9)

    assert store.staleness_note(hw) is None          # missing file: no nudge

    store.save(_fake_calibration(hw))                # registry_version=0
    note = store.staleness_note(hw)
    assert note is not None and "predates kernel registry" in note
    assert f"v{registry.REGISTRY_VERSION}" in note

    fresh = dataclasses.replace(_fake_calibration(hw),
                                registry_version=registry.REGISTRY_VERSION)
    store.save(fresh)
    assert store.staleness_note(hw) is None          # current: silent

    # Fingerprint drift beats version currency: the note explains why
    # load() refused the file and the dispatcher fell back to defaults.
    changed = dataclasses.replace(hw, peak_flops=hw.peak_flops * 2)
    note = store.staleness_note(changed)
    assert note is not None and "fingerprint" in note

    store.path_for(hw).write_text("{not json")
    assert "unreadable" in store.staleness_note(hw)


def test_staleness_note_reaches_plan_summary(tmp_path):
    from repro.kernels import registry
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=8e9)
    store = CalibrationStore(root=tmp_path)
    store.save(_fake_calibration(hw))                # stale (version 0)
    disp = sparse.Dispatcher(hardware=hw, calibration=store)
    plan = disp.plan(_mats()["random"], 8)
    assert plan.calibration_note is not None
    assert "predates kernel registry" in plan.summary()

    # Re-calibrating clears the nudge (refresh drops the note cache).
    store.save(dataclasses.replace(
        _fake_calibration(hw), registry_version=registry.REGISTRY_VERSION))
    disp.refresh_calibration()
    plan2 = disp.plan(_mats()["random"], 16)
    assert plan2.calibration_note is None
    assert "predates" not in plan2.summary()

    # calibration=False opts out of the nudge entirely.
    disp_off = sparse.Dispatcher(hardware=hw, calibration=False)
    assert disp_off.plan(_mats()["random"], 8).calibration_note is None


def test_calibrate_stamps_registry_version(tmp_path):
    from repro.kernels import registry
    hw = dataclasses.replace(HOST_CPU, hbm_bandwidth=8e9)
    store = CalibrationStore(root=tmp_path)
    calibrate(hw, backend="jax", scale=6, repeats=1, d_values=(4, 16),
              bcsr_block=16, store=store)
    payload = json.loads(store.path_for(hw).read_text())
    assert payload["registry_version"] == registry.REGISTRY_VERSION
    assert store.staleness_note(hw) is None
