"""Property tests for the paper's sparsity-aware AI models (Section III)."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # declared dev dep; CI installs the real one
    from _hypothesis_stub import given, settings, st

from repro.core import (
    PERLMUTTER_MILAN, TPU_V5E, ai_blocked, ai_blocked_tpu, ai_diagonal,
    ai_random, ai_scale_free, arithmetic_intensity,
    expected_occupied_columns, flops_spmm, hub_edge_fraction,
    mxu_utilization, place,
)

dims = st.integers(min_value=2 ** 10, max_value=2 ** 22)
degrees = st.floats(min_value=1.0, max_value=64.0)
widths = st.sampled_from([1, 4, 16, 64])


@given(n=dims, deg=degrees, d=widths)
@settings(max_examples=60, deadline=None)
def test_random_is_lower_bound(n, deg, d):
    """Random sparsity is the paper's worst case: lowest AI of all models."""
    nnz = int(n * deg)
    r = ai_random(n, nnz, d).ai
    assert r <= ai_diagonal(n, nnz, d).ai + 1e-12
    assert r <= ai_scale_free(n, nnz, d).ai + 1e-12


@given(n=dims, deg=degrees)
@settings(max_examples=40, deadline=None)
def test_ai_increases_with_d(n, deg):
    """More dense columns amortize index traffic: AI grows with d."""
    nnz = int(n * deg)
    for model, kwargs in [("random", {}), ("diagonal", {}),
                          ("scale_free", {})]:
        ais = [arithmetic_intensity(model, n, nnz, d, **kwargs).ai
               for d in (1, 4, 16, 64)]
        assert all(a < b for a, b in zip(ais, ais[1:])), (model, ais)


def test_paper_equations_exact():
    """Eqs. 2 and 3 match the published closed forms."""
    n, nnz, d = 2 ** 22, 10 * 2 ** 22, 16
    eq2 = (2 * d * nnz) / ((12 + 8 * d) * nnz + 8 * n * d)
    got = ai_random(n, nnz, d).ai
    # row_ptr is (n+1) ints, the paper folds it into ~12 nnz bytes
    assert got == pytest.approx(eq2, rel=0.02)
    eq3 = (2 * d * nnz) / (12 * nnz + 16 * n * d)
    assert ai_diagonal(n, nnz, d).ai == pytest.approx(eq3, rel=0.02)


def test_hub_fraction_paper_example():
    """Appendix: alpha=2.2, f=1% -> nnz_hub/nnz ~ 0.46."""
    assert hub_edge_fraction(2.2, 0.01) == pytest.approx(0.464, abs=0.01)


@given(alpha=st.floats(min_value=2.05, max_value=2.95),
       f=st.floats(min_value=1e-4, max_value=1.0))
@settings(max_examples=60, deadline=None)
def test_hub_fraction_bounds(alpha, f):
    h = hub_edge_fraction(alpha, f)
    assert 0.0 < h <= 1.0
    # More hubs can only carry more edge mass.
    assert hub_edge_fraction(alpha, min(1.0, f * 2)) >= h - 1e-12


@given(t=st.sampled_from([16, 64, 128, 256]),
       D=st.floats(min_value=0.1, max_value=1e4))
@settings(max_examples=60, deadline=None)
def test_occupied_columns_bounds(t, D):
    z = expected_occupied_columns(t, D)
    assert 0.0 <= z <= t
    # z is increasing in D and saturates at t.
    assert expected_occupied_columns(t, D * 2) >= z - 1e-9


def test_blocked_models():
    n, t = 2 ** 20, 128
    N = n // t
    nnz = N * 64                       # D = 64 per block
    cpu = ai_blocked(n, nnz, 16, t=t, num_blocks=N)
    tpu = ai_blocked_tpu(n, nnz, 16, t=t, num_blocks=N)
    assert cpu.ai > ai_random(n, nnz, 16).ai     # blocking helps
    assert 0 < mxu_utilization(nnz, t, N) < 1
    # TPU model moves whole dense blocks: more A traffic than CPU CSB.
    assert tpu.bytes_a > cpu.bytes_a


@given(d=widths, deg=degrees)
@settings(max_examples=40, deadline=None)
def test_traffic_consistency(d, deg):
    n = 2 ** 16
    nnz = int(n * deg)
    tb = ai_random(n, nnz, d)
    assert tb.flops == flops_spmm(nnz, d)
    assert tb.ai == pytest.approx(tb.flops / tb.total_bytes)


def test_roofline_placement():
    n, nnz, d = 2 ** 22, 10 * 2 ** 22, 16
    tb = ai_random(n, nnz, d)
    pt = place("er_22_10", tb, PERLMUTTER_MILAN, attained=10e9)
    assert pt.bound == "memory"          # SpMM is memory bound (paper II-C)
    assert pt.attainable_flops_per_s == pytest.approx(
        PERLMUTTER_MILAN.hbm_bandwidth * tb.ai)
    assert 0 < pt.roofline_fraction < 1.5
    # v5e ridge point: ~240 FLOP/byte, far above any SpMM AI.
    assert TPU_V5E.ridge_point > 100


def test_model_dispatch_unknown():
    with pytest.raises(ValueError):
        arithmetic_intensity("nope", 10, 10, 1)
