"""Jit'd public wrappers around the Pallas kernels.

Handles layout preparation (empty-block-row padding, band extraction),
backend selection (interpret=True anywhere but real TPU), and exposes the
paper's roofline estimate for each kernel invocation so callers can place
the launch on the sparsity-aware roofline before running it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity_models as sm
from repro.core.hardware import TPU_V5E
from repro.kernels.bcsr_spmm import bcsr_spmm_pallas
from repro.kernels.banded_spmm import banded_spmm_pallas
from repro.kernels.csr_spmm import csr_spmm_pallas, csr_to_row_tiles
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.sparse.formats import BCSRMatrix, CSRMatrix


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return (not _on_tpu()) if flag is None else flag


def pad_empty_block_rows(a: BCSRMatrix) -> BCSRMatrix:
    """Ensure every block row owns >= 1 block (zero block on the diagonal).

    The Pallas kernel writes a C tile only when its block row is visited;
    padding guarantees total coverage without in-kernel masking.
    """
    nb = a.nb
    present = np.zeros(nb, dtype=bool)
    rows_np = np.asarray(a.block_rows)
    present[rows_np] = True
    missing = np.nonzero(~present)[0].astype(np.int32)
    if missing.size == 0:
        return a
    blocks = jnp.concatenate(
        [a.blocks, jnp.zeros((missing.size, a.t, a.t), a.blocks.dtype)])
    rows = np.concatenate([rows_np, missing])
    cols = np.concatenate([np.asarray(a.block_cols), missing])
    order = np.argsort(rows, kind="stable")
    counts = np.bincount(rows, minlength=nb)
    ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return BCSRMatrix(
        blocks=blocks[jnp.asarray(order)],
        block_rows=jnp.asarray(rows[order].astype(np.int32)),
        block_cols=jnp.asarray(cols[order].astype(np.int32)),
        block_ptr=jnp.asarray(ptr),
        n=a.n, t=a.t, nnz=a.nnz,
    )


def bcsr_spmm(a: BCSRMatrix, b: jnp.ndarray, *, block_d: int = 512,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """BCSR SpMM via the Pallas kernel (paper's CSB on TPU).

    Args:
        a: dense-block container, [n, n] with t x t blocks; empty block
            rows are zero-padded here so the kernel covers every C tile.
        b: dense right-hand side, [n, d]; when d > ``block_d``, d must be
            a multiple of ``block_d`` (the tile clamps to min(block_d, d)).
        block_d: d-tile width the kernel iterates over.
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``C = A @ B`` as a dense [n, d] array.
    """
    a = pad_empty_block_rows(a)
    return bcsr_spmm_pallas(a.blocks, a.block_rows, a.block_cols, b,
                            n=a.n, t=a.t, block_d=block_d,
                            interpret=_interpret(interpret))


def csr_spmm(a: CSRMatrix, b: jnp.ndarray, *, row_tile: int = 8,
             chunk: int = 128, block_d: int = 512,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """CSR SpMM via the Pallas row-gather/segment-sum kernel.

    Packs the CSR arrays into row-tiled chunks host-side (cached nowhere:
    callers that reuse a matrix should go through repro.sparse.dispatch,
    which caches conversions per matrix).

    Args:
        a: CSR container, [n, n].
        b: dense right-hand side, [n, d]; when d > ``block_d``, d must be
            a multiple of ``block_d`` (the tile clamps to min(block_d, d)).
        row_tile: rows handled per kernel program.
        chunk: nonzeros packed per (tile, chunk) slot.
        block_d: d-tile width the kernel iterates over.
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``C = A @ B`` as a dense [n, d] array.
    """
    tiles, cols, slots, vals = csr_to_row_tiles(
        np.asarray(a.indptr), np.asarray(a.indices), np.asarray(a.data),
        n=a.n, row_tile=row_tile, chunk=chunk)
    return csr_spmm_pallas(jnp.asarray(tiles), jnp.asarray(cols),
                           jnp.asarray(slots), jnp.asarray(vals), b,
                           n=a.n, row_tile=row_tile, block_d=block_d,
                           interpret=_interpret(interpret))


def banded_spmm(band: jnp.ndarray, b: jnp.ndarray, *, t: int, w: int,
                block_d: int = 512,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Banded SpMM via the Pallas kernel (paper's diagonal regime).

    Args:
        band: block-band tensor [nb, 2w+1, t, t] from ``band_to_blocks``.
        b: dense right-hand side, [n, d] with n = nb * t.
        t: block edge; must divide n.
        w: block half-bandwidth (diagonal offsets within ±w*t).
        block_d: d-tile width the kernel iterates over.
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``C = A @ B`` as a dense [n, d] array.
    """
    return banded_spmm_pallas(band, b, t=t, w=w, block_d=block_d,
                              interpret=_interpret(interpret))


def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray, group_ids: jnp.ndarray,
                   *, bm: int = 128, bk: int = 128, bn: int = 128,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Grouped (block-diagonal) matmul via the Pallas kernel (MoE FFN).

    Args:
        x: token rows sorted/padded into ``bm``-row group blocks, [T, K].
        w: per-group weights, [E, K, N].
        group_ids: group index per ``bm``-row block, [T / bm] int32.
        bm, bk, bn: MXU tile sizes (rows, contraction, columns).
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``Y[i] = x[i] @ w[group_ids[i // bm]]`` as a dense [T, N] array.
    """
    return grouped_matmul_pallas(x, w, group_ids, bm=bm, bk=bk, bn=bn,
                                 interpret=_interpret(interpret))


def band_to_blocks(dia_data: np.ndarray, offsets, *, n: int, t: int):
    """Convert DIA storage to the kernel's block-band tensor.

    Args:
        dia_data: DIA values, [num_offsets, n] indexed by row.
        offsets: diagonal offsets matching ``dia_data`` rows.
        n: matrix dimension; t must divide n for the kernel grid.
        t: block edge of the band tensor.

    Returns:
        ``(band, w)``: band tensor [nb, 2w+1, t, t] (nb = n / t) and the
        block half-bandwidth w, as consumed by :func:`banded_spmm`.
    """
    nb = (n + t - 1) // t
    max_off = max(abs(int(o)) for o in offsets) if len(offsets) else 0
    w = (max_off + t - 1) // t
    band = np.zeros((nb, 2 * w + 1, t, t), dtype=np.asarray(dia_data).dtype)
    dia = np.asarray(dia_data)
    for oi, off in enumerate(offsets):
        off = int(off)
        for r in range(n):
            c = r + off
            if 0 <= c < n and dia[oi, r] != 0:
                bi, bj = r // t, c // t
                band[bi, bj - bi + w, r % t, c % t] = dia[oi, r]
    return jnp.asarray(band), w


@dataclasses.dataclass(frozen=True)
class KernelRoofline:
    """Sparsity-aware placement of one kernel launch on the v5e roofline."""

    name: str
    ai: float
    useful_flops: float
    mxu_flops: float
    attainable_flops_per_s: float
    mxu_utilization: float


def csr_kernel_roofline(a: CSRMatrix, d: int, *,
                        regime: str = "random") -> KernelRoofline:
    """Place a CSR kernel launch on the v5e roofline under its regime model.

    The CSR kernel issues exactly the useful FLOPs (padding slots multiply
    zeros, a negligible <1/chunk overhead), so MXU utilization is reported
    as 1.0; what varies with structure is the B-traffic term of the AI.
    """
    tb = sm.arithmetic_intensity(regime, a.n, a.nnz, d,
                                 sizeof_val=a.data.dtype.itemsize)
    return KernelRoofline(
        name="csr_spmm", ai=tb.ai, useful_flops=tb.flops,
        mxu_flops=tb.flops,
        attainable_flops_per_s=TPU_V5E.attainable(tb.ai),
        mxu_utilization=1.0)


def bcsr_kernel_roofline(a: BCSRMatrix, d: int) -> KernelRoofline:
    """Apply the TPU blocked model (DESIGN.md Section 3) to a launch."""
    tb = sm.ai_blocked_tpu(a.n, a.nnz, d, t=a.t, num_blocks=a.num_blocks,
                           sizeof_val=a.blocks.dtype.itemsize)
    util = sm.mxu_utilization(a.nnz, a.t, a.num_blocks)
    return KernelRoofline(
        name="bcsr_spmm", ai=tb.ai, useful_flops=tb.flops,
        mxu_flops=2.0 * d * a.t * a.t * a.num_blocks,
        attainable_flops_per_s=TPU_V5E.attainable(tb.ai),
        mxu_utilization=util)


def grouped_matmul_roofline(T: int, K: int, N: int, E: int, *,
                            itemsize: int = 2) -> KernelRoofline:
    """Block-diagonal case: every block dense => MXU utilization 1.0."""
    flops = 2.0 * T * K * N
    bytes_moved = itemsize * (T * K + E * K * N + T * N)
    ai = flops / bytes_moved
    return KernelRoofline(
        name="grouped_matmul", ai=ai, useful_flops=flops, mxu_flops=flops,
        attainable_flops_per_s=TPU_V5E.attainable(ai), mxu_utilization=1.0)
