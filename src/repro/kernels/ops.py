"""Container-level compat wrappers over the kernel registry.

The registry (``repro.kernels.registry``) is the system entry point: one
:class:`~repro.kernels.registry.KernelSpec` per ``(format, backend)``
pair, consumed by the dispatcher, the streaming layer, the calibration
sweep, and the benchmark suite.  This module keeps the original
container-level call signatures (``csr_spmm(CSRMatrix, b)`` etc.) for
direct kernel use and the kernel test sweeps; layout helpers and the
roofline-estimate types live in the registry and are re-exported here.

The wrappers are deprecated: they run fp32/int32 only and do not grow
the precision axis (value/index dtype selection lives in
:class:`~repro.kernels.registry.KernelContext`).  New callers should use
``registry.spmm(m, b, format=..., backend=...)`` or bind a
:class:`~repro.kernels.registry.KernelSpec`; each wrapper raises a
``DeprecationWarning`` on call.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Re-exported for backward compatibility: these moved to the registry.
from repro.kernels.registry import (          # noqa: F401
    KernelRoofline, band_to_blocks, bcsr_kernel_roofline,
    csr_kernel_roofline, dia_kernel_roofline, grouped_matmul_roofline,
    pad_empty_block_rows,
)
from repro.kernels.bcsr_spmm import bcsr_spmm_pallas
from repro.kernels.banded_spmm import banded_spmm_pallas
from repro.kernels.binned_spmm import (
    binned_spmm_pallas, csr_to_slab_bins, pack_rowsplit_chunks,
    rowsplit_spmm_pallas)
from repro.kernels.csr_spmm import csr_spmm_pallas, csr_to_row_tiles
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.sparse.formats import BCSRMatrix, CSRMatrix


def _interpret(flag: Optional[bool]) -> bool:
    return (jax.default_backend() != "tpu") if flag is None else flag


def _warn_deprecated(name: str) -> None:
    # stacklevel=3: helper frame (1), wrapper frame (2), caller (3).
    warnings.warn(
        f"repro.kernels.{name} is a deprecated fp32/int32-only compat "
        f"wrapper; use repro.kernels.registry.spmm(m, b, format=..., "
        f"backend='pallas') with a KernelContext (which also carries the "
        f"value/index precision axis), or the dispatcher in "
        f"repro.sparse",
        DeprecationWarning, stacklevel=3)


def bcsr_spmm(a: BCSRMatrix, b: jnp.ndarray, *, block_d: int = 512,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """BCSR SpMM via the Pallas kernel (paper's CSB on TPU).

    Args:
        a: dense-block container, [n, n] with t x t blocks; empty block
            rows are zero-padded here so the kernel covers every C tile.
        b: dense right-hand side, [n, d]; when d > ``block_d``, d must be
            a multiple of ``block_d`` (the tile clamps to min(block_d, d)).
        block_d: d-tile width the kernel iterates over.
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``C = A @ B`` as a dense [n, d] array.
    """
    _warn_deprecated("bcsr_spmm")
    a = pad_empty_block_rows(a)
    return bcsr_spmm_pallas(a.blocks, a.block_rows, a.block_cols, b,
                            n=a.n, t=a.t, block_d=block_d,
                            interpret=_interpret(interpret))


def csr_spmm(a: CSRMatrix, b: jnp.ndarray, *, row_tile: int = 8,
             chunk: int = 128, block_d: int = 512,
             b_tile: Optional[int] = None,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """CSR SpMM via the Pallas row-gather/segment-sum kernel.

    Packs the CSR arrays into row-tiled chunks host-side (cached nowhere:
    callers that reuse a matrix should go through repro.sparse.dispatch,
    which caches prepared layouts per matrix).

    Args:
        a: CSR container, [n, n].
        b: dense right-hand side, [n, d]; when d > ``block_d``, d must be
            a multiple of ``block_d`` (the tile clamps to min(block_d, d)).
        row_tile: rows handled per kernel program.
        chunk: nonzeros packed per (tile, chunk) slot.
        block_d: d-tile width the kernel iterates over.
        b_tile: B rows per VMEM-resident slab; None holds B whole.  The
            dispatcher picks this from ``HardwareSpec.vmem_bytes`` so the
            kernel streams B past VMEM (``registry.choose_b_tile``).
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``C = A @ B`` as a dense [n, d] array.
    """
    _warn_deprecated("csr_spmm")
    tiles, slabs, cols, slots, vals = csr_to_row_tiles(
        np.asarray(a.indptr), np.asarray(a.indices), np.asarray(a.data),
        n=a.n, row_tile=row_tile, chunk=chunk, b_tile=b_tile)
    return csr_spmm_pallas(jnp.asarray(tiles), jnp.asarray(slabs),
                           jnp.asarray(cols), jnp.asarray(slots),
                           jnp.asarray(vals), b, n=a.n, row_tile=row_tile,
                           b_tile=b_tile, block_d=block_d,
                           interpret=_interpret(interpret))


def banded_spmm(band: jnp.ndarray, b: jnp.ndarray, *, t: int, w: int,
                block_d: int = 512,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Banded SpMM via the Pallas kernel (paper's diagonal regime).

    Args:
        band: block-band tensor [nb, 2w+1, t, t] from ``band_to_blocks``.
        b: dense right-hand side, [n, d] with n = nb * t.
        t: block edge; must divide n.
        w: block half-bandwidth (diagonal offsets within ±w*t).
        block_d: d-tile width the kernel iterates over.
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``C = A @ B`` as a dense [n, d] array.
    """
    _warn_deprecated("banded_spmm")
    return banded_spmm_pallas(band, b, t=t, w=w, block_d=block_d,
                              interpret=_interpret(interpret))


def binned_spmm(a: CSRMatrix, b: jnp.ndarray, *, row_tile: int = 8,
                chunk: int = 128, block_d: int = 512,
                b_tile: Optional[int] = None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Two-phase binned SpMM via the Pallas slab-major kernel.

    Bins the CSR nonzeros by B-row slab host-side, so the kernel touches
    each VMEM-resident slab of B exactly once per d-pass and streams
    partial C blocks instead of streaming gathers (the scale-free
    regime's propagation-blocking traversal).

    Args:
        a: CSR container, [n, n] (the binning starts from CSR order).
        b: dense right-hand side, [n, d]; when d > ``block_d``, d must be
            a multiple of ``block_d`` (the tile clamps to min(block_d, d)).
        row_tile: rows per partial C block.
        chunk: nonzeros packed per kernel step.
        b_tile: B rows per VMEM-resident slab; None holds B whole (one
            slab — degenerates to CSR order).
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``C = A @ B`` as a dense [n, d] array.
    """
    _warn_deprecated("binned_spmm")
    arrays = csr_to_slab_bins(
        np.asarray(a.indptr), np.asarray(a.indices), np.asarray(a.data),
        n=a.n, row_tile=row_tile, chunk=chunk, b_tile=b_tile)
    return binned_spmm_pallas(*(jnp.asarray(x) for x in arrays), b,
                              n=a.n, row_tile=row_tile, b_tile=b_tile,
                              block_d=block_d,
                              interpret=_interpret(interpret))


def rowsplit_spmm(a: CSRMatrix, b: jnp.ndarray, *, chunk: int = 128,
                  block_d: int = 512,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Row-split (merge-path) SpMM via the Pallas equal-nnz-chunk kernel.

    Cuts the nonzero stream into exact-``chunk`` work units so skewed
    degree distributions (hub rows) cannot starve kernel programs, then
    scatters the windowed partials back by row in a segment-sum epilogue.

    Args:
        a: CSR container, [n, n].
        b: dense right-hand side, [n, d]; held whole in VMEM (this kernel
            trades B residency for perfect load balance).
        chunk: nonzeros per work unit.
        block_d: d-tile width the kernel iterates over.
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``C = A @ B`` as a dense [n, d] array.
    """
    _warn_deprecated("rowsplit_spmm")
    row_map, cols, slots, vals = pack_rowsplit_chunks(
        np.asarray(a.indptr), np.asarray(a.indices), np.asarray(a.data),
        n=a.n, chunk=chunk)
    return rowsplit_spmm_pallas(
        jnp.asarray(row_map), jnp.asarray(cols), jnp.asarray(slots),
        jnp.asarray(vals), b, n=a.n, window=int(row_map.shape[1]),
        block_d=block_d, interpret=_interpret(interpret))


def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray, group_ids: jnp.ndarray,
                   *, bm: int = 128, bk: int = 128, bn: int = 128,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Grouped (block-diagonal) matmul via the Pallas kernel (MoE FFN).

    Args:
        x: token rows sorted/padded into ``bm``-row group blocks, [T, K].
        w: per-group weights, [E, K, N].
        group_ids: group index per ``bm``-row block, [T / bm] int32.
        bm, bk, bn: MXU tile sizes (rows, contraction, columns).
        interpret: force Pallas interpret mode; default: off-TPU only.

    Returns:
        ``Y[i] = x[i] @ w[group_ids[i // bm]]`` as a dense [T, N] array.
    """
    _warn_deprecated("grouped_matmul")
    return grouped_matmul_pallas(x, w, group_ids, bm=bm, bk=bk, bn=bn,
                                 interpret=_interpret(interpret))
