"""Pallas TPU kernel: BCSR (block-compressed-sparse-row) SpMM.

TPU adaptation of the paper's CSB implementation (DESIGN.md Section 3).  A is
stored as dense t x t blocks; the kernel walks the nonzero blocks in
block-row-major order on the Pallas grid, DMAs each A block and the matching
t x bd tile of B HBM->VMEM, and accumulates C tiles in VMEM with MXU matmuls.

Grid layout: ``(d_tiles, num_blocks)`` with the block index innermost, so all
blocks of a block row are processed consecutively and the C tile stays
resident in VMEM until the block row changes (the paper's cache-reuse
argument made deterministic).  Block coordinates arrive via scalar prefetch,
which the TPU uses to program the DMA engine ahead of compute.

VMEM working set per grid step:
    A block  t*t*4           (e.g. 128x128 fp32 = 64 KiB)
    B tile   t*bd*4          (128x512     fp32 = 256 KiB)
    C tile   t*bd*4          (128x512     fp32 = 256 KiB)
well under the ~128 MiB v5e VMEM; t and bd default to MXU-aligned 128/512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bcsr_kernel(rows_ref, cols_ref, a_ref, b_ref, o_ref):
    """One grid step: o[rows[i]] += a[i] @ b[cols[i]] (accumulated in VMEM)."""
    del cols_ref  # consumed by the B index map
    i_n = pl.program_id(1)
    # First visit of this C tile in this d-pass: previous block was a
    # different block row (or this is the first block).
    is_first = (i_n == 0) | (rows_ref[i_n] != rows_ref[i_n - 1])

    @pl.when(is_first)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_block = a_ref[0]                      # [t, t]
    b_tile = b_ref[...]                     # [t, bd]
    o_ref[...] += jnp.dot(a_block, b_tile,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n", "t", "block_d", "interpret"))
def bcsr_spmm_pallas(blocks: jnp.ndarray, block_rows: jnp.ndarray,
                     block_cols: jnp.ndarray, b: jnp.ndarray, *, n: int,
                     t: int, block_d: int = 512,
                     interpret: bool = True) -> jnp.ndarray:
    """C = A @ B with A given as sorted nonzero blocks.

    Args:
      blocks:     [N, t, t] dense block values, sorted by (block_row, col).
      block_rows: [N] int32 block-row ids. Every block row in [0, n/t) must
                  appear at least once (pad empty rows with a zero block —
                  see ops.pad_empty_block_rows).
      block_cols: [N] int32 block-col ids.
      b:          [n, d] dense operand.
      n, t:       matrix dim and block edge (static).
      block_d:    d-tile width (static, MXU-aligned).
      interpret:  run in interpret mode (CPU correctness path).
    """
    d = b.shape[1]
    bd = min(block_d, d)
    if d % bd != 0:
        raise ValueError(f"d={d} must be divisible by the d-tile {bd}")
    num_blocks = blocks.shape[0]
    nb = n // t
    grid = (d // bd, num_blocks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, t), lambda i_d, i_n, rows, cols: (i_n, 0, 0)),
            pl.BlockSpec((t, bd),
                         lambda i_d, i_n, rows, cols: (cols[i_n], i_d)),
        ],
        out_specs=pl.BlockSpec((t, bd),
                               lambda i_d, i_n, rows, cols: (rows[i_n], i_d)),
    )
    out = pl.pallas_call(
        _bcsr_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb * t, d), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, blocks, b)
    return out[:n].astype(b.dtype)
