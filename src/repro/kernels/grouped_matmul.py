"""Pallas TPU kernel: grouped matmul (block-diagonal BCSR SpMM for MoE).

MegaBlocks insight, restated in the paper's terms: after sorting tokens by
routed expert, the MoE expert FFN is an SpMM whose A is *block-diagonal* —
the best case of the paper's blocked-sparsity regime (every t x t block is
fully dense, z = t, MXU utilization 1.0).  The kernel computes

    out[i*bm:(i+1)*bm] = x[i*bm:(i+1)*bm] @ w[group_ids[i]]

i.e. each row block of the sorted token buffer multiplies the weight matrix
of the expert that owns it.  ``group_ids`` arrives via scalar prefetch so the
weight DMA for block i+1 can be issued while block i is on the MXU.

Grid: (row_blocks, n_tiles, k_tiles), k innermost for VMEM accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(gid_ref, x_ref, w_ref, o_ref):
    del gid_ref  # consumed by the W index map
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                          preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def grouped_matmul_pallas(x: jnp.ndarray, w: jnp.ndarray,
                          group_ids: jnp.ndarray, *, bm: int = 128,
                          bk: int = 128, bn: int = 128,
                          interpret: bool = True) -> jnp.ndarray:
    """out[r] = x[r] @ w[group_of_row_block(r)].

    Args:
      x:         [T, K] sorted token buffer (T divisible by bm).
      w:         [E, K, N] expert weights.
      group_ids: [T // bm] int32 expert id per row block.  Rows within one
                 block must share an expert (guaranteed by the dispatcher's
                 block-aligned padding).
      bm/bk/bn:  tile sizes (MXU-aligned).
    """
    T, K = x.shape
    E, K2, N = w.shape
    assert K == K2, (K, K2)
    if T % bm or K % bk or N % bn:
        raise ValueError(f"shapes ({T},{K},{N}) not divisible by tiles "
                         f"({bm},{bk},{bn})")
    grid = (T // bm, N // bn, K // bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, gid: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, gid: (gid[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, gid: (i, j)),
    )
    out = pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.float32),
        interpret=interpret,
    )(group_ids, x, w)
    return out.astype(x.dtype)
