"""Pallas TPU kernels for the paper's compute hot-spots (SpMM variants)."""
from repro.kernels.ops import (
    band_to_blocks, banded_spmm, bcsr_kernel_roofline, bcsr_spmm,
    csr_kernel_roofline, csr_spmm, grouped_matmul, grouped_matmul_roofline,
    pad_empty_block_rows,
)
__all__ = [
    "band_to_blocks", "banded_spmm", "bcsr_kernel_roofline", "bcsr_spmm",
    "csr_kernel_roofline", "csr_spmm", "grouped_matmul",
    "grouped_matmul_roofline", "pad_empty_block_rows",
]
