"""Pallas TPU kernels for the paper's compute hot-spots (SpMM variants).

``repro.kernels.registry`` is the uniform entry point: one ``KernelSpec``
(layout prep, launch, roofline estimate, VMEM footprint) per
``(format, backend)`` pair.  The container-level wrappers below are kept
for direct kernel use.
"""
from repro.kernels import registry
from repro.kernels.ops import (
    band_to_blocks, banded_spmm, bcsr_kernel_roofline, bcsr_spmm,
    binned_spmm, csr_kernel_roofline, csr_spmm, dia_kernel_roofline,
    grouped_matmul, grouped_matmul_roofline, pad_empty_block_rows,
    rowsplit_spmm,
)
from repro.kernels.registry import (
    KernelContext, KernelRoofline, KernelSpec, choose_b_tile,
    feature_matrix, formats_for,
)

__all__ = [
    "registry",
    "band_to_blocks", "banded_spmm", "bcsr_kernel_roofline", "bcsr_spmm",
    "binned_spmm", "csr_kernel_roofline", "csr_spmm",
    "dia_kernel_roofline", "grouped_matmul", "grouped_matmul_roofline",
    "pad_empty_block_rows", "rowsplit_spmm",
    "KernelContext", "KernelRoofline", "KernelSpec", "choose_b_tile",
    "feature_matrix", "formats_for",
]
