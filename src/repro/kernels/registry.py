"""Kernel registry: every SpMM kernel behind one ``KernelSpec`` interface.

The dispatcher used to hard-code one executor branch per format — layout
packing, kernel call, and VMEM assumptions scattered between
``sparse/dispatch.py`` and ``kernels/ops.py``.  This module makes the
kernel layer uniform: each ``(format, backend)`` pair registers a
:class:`KernelSpec` bundling

  * ``prepare(m, ctx)``  — one-time host-side layout prep (format
    conversion, row-tile chunking, band extraction, empty-row padding);
  * ``run(layout, b, ctx)`` — the per-call kernel launch (Pallas call or
    pure-JAX implementation), tile widths adapted to ``b``;
  * ``estimate(m, d, ctx)`` — the sparsity-aware roofline placement of a
    launch (AI, useful vs issued FLOPs, attainable GFLOP/s);
  * ``vmem_footprint(n, d, ctx)`` — the kernel's modeled resident VMEM
    working set in bytes (0 for XLA-managed jax backends).

``repro.sparse.dispatch.Dispatcher.executor`` resolves the winning plan
through :func:`get`; ``repro.sparse.stream`` replays the bound closure;
``benchmarks/spmm_suite.py`` validates its format list against
:func:`formats_for`; and ``repro.core.calibrate`` sweeps every registered
spec to fit measured compute ceilings.  :func:`spmm` is the one-call
registry entry point for direct use.

The CSR Pallas spec is where the VMEM model matters: ``prepare`` picks the
B row-slab size from ``ctx.hardware.vmem_bytes`` (``choose_b_tile``), so
the kernel streams B slab-by-slab and stays eligible at any ``n`` instead
of capping out at ``n * bd * 4 <= VMEM``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity_models as sm
from repro.core.hardware import HOST_CPU, TPU_V5E, HardwareSpec
from repro.core.precision import DEFAULT_PRECISION, Precision
from repro.kernels.banded_spmm import banded_spmm_pallas
from repro.kernels.bcsr_spmm import bcsr_spmm_pallas
from repro.kernels.binned_spmm import (
    binned_spmm_pallas, csr_to_slab_bins, pack_rowsplit_chunks,
    rowsplit_spmm_pallas)
from repro.kernels.csr_spmm import csr_spmm_pallas, csr_to_row_tiles
from repro.kernels.grouped_matmul import grouped_matmul_pallas

BACKENDS: Tuple[str, ...] = ("jax", "pallas")

#: Monotone version of the registered kernel set and their layout/sizing
#: rules.  Bump it whenever a change invalidates previously measured
#: compute ceilings (new kernels, retuned slab sizing, layout changes);
#: ``repro.core.calibrate`` stamps saved calibrations with it so
#: ``plan.summary()`` can nudge when a calibration predates the kernels
#: it would be applied to.  History: 1 = initial KernelSpec registry,
#: 2 = per-d B-slab re-packing (``KernelContext.plan_d``),
#: 3 = scale-free kernel tier (binned / rowsplit / ell_coo),
#: 4 = precision axis (bf16 values / int16 indices; dtype-sized slabs
#: and footprints).
REGISTRY_VERSION: int = 4


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pallas_block_d(d: int) -> int:
    """Largest d-tile (<= 512) dividing d; the kernels require d % bd == 0."""
    for bd in (512, 256, 128, 64, 32, 16, 8, 4, 2):
        if d % bd == 0:
            return bd
    return 1


def pallas_band_tile(n: int) -> int:
    """Largest MXU-friendly tile edge dividing n (banded Pallas kernel)."""
    for t in (128, 64, 32, 16, 8, 4, 2):
        if n % t == 0:
            return t
    return 1


def choose_b_tile(n: int, vmem_bytes: int, *, bd: int = 512,
                  sizeof_val: int = 4) -> Optional[int]:
    """B row-slab size for the streamed CSR kernel, from the VMEM budget.

    Half the VMEM goes to the resident B slab (the rest covers the C tile,
    index chunks, gather scratch, and double buffering).  Returns ``None``
    when all of B fits — the layout then reduces to the unstreamed
    original (one slab, global column ids).

    ``bd`` is the kernel's d-tile width the slab must host.  The default
    512 is the widest tile — safe for any ``d`` but, when the planned
    width is far below it, it undersizes the slab by the ratio
    ``512 / bd`` (the budget is charged for columns that never
    materialize).  Callers that know ``d`` at plan time pass the actual
    tile (``KernelContext.plan_d`` routes this through
    ``resolve_b_tile``), so small-d plans get proportionally taller
    slabs and fewer slab passes.
    """
    if vmem_bytes <= 0:
        return None
    slab_rows = (vmem_bytes // 2) // (bd * sizeof_val)
    if slab_rows >= n:
        return None
    return max(8, int(slab_rows) // 8 * 8)


@dataclasses.dataclass(frozen=True)
class KernelContext:
    """Knobs a :class:`KernelSpec` needs to prepare and launch.

    Attributes:
        hardware: ceilings of the target device; ``vmem_bytes`` drives the
            streamed-CSR slab size and the footprint models.
        bcsr_block: BCSR block edge t.
        max_dia_offsets: DIA conversion cap (mirrors the dispatch policy).
        interpret: force Pallas interpret mode; None = off-TPU only.
        row_tile: CSR kernel rows per C tile.
        chunk: CSR kernel nonzeros per packed chunk.
        b_tile: explicit B row-slab override for the streamed CSR kernel;
            None picks it from ``hardware.vmem_bytes`` (``choose_b_tile``).
        plan_d: the dense width the plan was made for, when known; lets
            ``resolve_b_tile`` size the B slab for the actual d-tile
            instead of the worst-case 512 (per-d slab re-packing).  None
            keeps the conservative sizing.
        precision: value/index storage dtypes the layouts are packed at
            (``repro.core.precision.Precision``); sizes the VMEM slab
            budget and footprints by the actual element widths.
        convert: optional ``(m, format) -> container`` hook so prepare
            reuses the caller's conversion cache (the dispatcher passes
            its own ``convert`` method, bound to this precision); None
            converts directly at ``precision``'s value dtype.
    """

    hardware: HardwareSpec = HOST_CPU
    bcsr_block: int = 64
    max_dia_offsets: int = 64
    interpret: Optional[bool] = None
    row_tile: int = 8
    chunk: int = 128
    b_tile: Optional[int] = None
    plan_d: Optional[int] = None
    precision: Precision = DEFAULT_PRECISION
    convert: Optional[Callable[[Any, str], Any]] = None

    def resolve_interpret(self) -> bool:
        """Pallas interpret flag: forced value, else off-TPU only."""
        return (not _on_tpu()) if self.interpret is None else self.interpret

    def resolve_b_tile(self, n: int) -> Optional[int]:
        """The streamed-CSR slab size for an ``[n, n]`` matrix.

        The slab budget is charged at the operand's actual element size,
        so bf16 streams get 2x taller slabs than fp32 for the same VMEM.
        """
        if self.b_tile is not None:
            return self.b_tile if self.b_tile < n else None
        bd = 512 if self.plan_d is None else min(512,
                                                 pallas_block_d(self.plan_d))
        return choose_b_tile(n, self.hardware.vmem_bytes, bd=bd,
                             sizeof_val=self.precision.sizeof_val)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel: layout prep, launch, estimate, VMEM model."""

    format: str                  # "csr" | "ell" | "bcsr" | "dia" | "binned"
    #                            # | "rowsplit" | "ell_coo" | "grouped"
    backend: str                 # "jax" | "pallas"
    description: str
    prepare: Callable[[Any, KernelContext], Any]
    run: Callable[[Any, jnp.ndarray, KernelContext], jnp.ndarray]
    estimate: Callable[[Any, int, KernelContext], "KernelRoofline"]
    vmem_footprint: Callable[[int, int, KernelContext], int]
    #: Specs producing identical prepared layouts share this key so
    #: callers cache one layout for all of them (ELL's pallas pick lowers
    #: to the CSR kernel and reuses its row-tile packing verbatim).
    layout_key: Optional[str] = None
    #: Execution metadata the serving engine (``repro.sparse.engine``)
    #: consults when staging right-hand sides.
    #:
    #: ``async_dispatch``: ``run`` only *enqueues* the launch and returns
    #: before the result materializes (every XLA-lowered kernel — jax
    #: eager ops, jitted shard_map programs, and pallas_call all dispatch
    #: asynchronously; completion is observed at ``block_until_ready``).
    #: The engine overlaps host→device staging of the next micro-batch
    #: with device compute of the current one only when this is set; a
    #: synchronous host kernel would make that overlap a lie.
    async_dispatch: bool = True
    #: ``donate_b``: the launch may alias B's device buffer for its
    #: output (``input_output_aliases`` / jit donation), so the caller
    #: must treat the staged buffer as consumed at dispatch.  None of the
    #: registered kernels alias B today — C has B's shape but every
    #: kernel reads B throughout the launch — so the engine keeps its
    #: staging buffer alive until materialization unless this flips.
    donate_b: bool = False
    #: What ``prepare``/``bind`` accept as the matrix operand.  ``"coo"``
    #: specs take a ``repro.core.patterns.COOMatrix`` and compute
    #: ``C = A @ B`` — the contract the cross-kernel differential suite
    #: (``tests/test_differential.py``) verifies against the dense
    #: reference for every registered pair.  Specs with another operand
    #: (the MoE grouped matmul's ``(w, group_ids, bm, bk, bn)`` tuple)
    #: declare it here so generic sweeps can skip them explicitly
    #: instead of special-casing format names.
    operand: str = "coo"
    #: Precision tokens (``Precision.token``) this kernel can execute.
    #: Every spec speaks fp32+int32; jax-backend specs add bf16 values
    #: over their int32 containers; the Pallas packers that store
    #: slab-local / chunk-local indices add compact int16 too (legality
    #: of a *particular* matrix is still checked at prepare time — an
    #: extent past ``2**15 - 1`` raises ``ValueError``).
    supported_precisions: Tuple[str, ...] = ("f32i32",)

    def supports_precision(self, precision: Precision) -> bool:
        """True iff this kernel can execute at ``precision``."""
        return precision.token in self.supported_precisions

    @property
    def key(self) -> Tuple[str, str]:
        """The registry key, ``(format, backend)``."""
        return (self.format, self.backend)

    @property
    def layout_cache_key(self) -> Tuple[str, str]:
        """Cache identity of ``prepare``'s output, ``(layout, backend)``."""
        return (self.layout_key or self.format, self.backend)

    def bind(self, m, ctx: KernelContext) -> Callable[[jnp.ndarray],
                                                      jnp.ndarray]:
        """Prepare the layout for ``m`` once and return ``run(b) -> c``."""
        layout = self.prepare(m, ctx)
        return lambda b: self.run(layout, b, ctx)


_REGISTRY: Dict[Tuple[str, str], KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    """Add ``spec`` under ``(spec.format, spec.backend)``; reject dupes."""
    if spec.key in _REGISTRY:
        raise ValueError(f"kernel {spec.key} already registered")
    _REGISTRY[spec.key] = spec
    return spec


def get(format: str, backend: str) -> KernelSpec:
    """Resolve the spec for ``(format, backend)``.

    Raises:
        KeyError: when the pair is unregistered; the message lists what is.
    """
    try:
        return _REGISTRY[(format, backend)]
    except KeyError:
        raise KeyError(
            f"no kernel registered for format={format!r} "
            f"backend={backend!r}; available: {sorted(_REGISTRY)}") from None


def specs() -> Tuple[KernelSpec, ...]:
    """All registered specs, sorted by (format, backend)."""
    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


def formats_for(backend: str) -> Tuple[str, ...]:
    """Formats with a kernel registered under ``backend``."""
    return tuple(sorted(f for f, b in _REGISTRY if b == backend))


def feature_matrix() -> Dict[Tuple[str, str], str]:
    """(format, backend) -> one-line description, for docs and tests."""
    return {k: _REGISTRY[k].description for k in sorted(_REGISTRY)}


def spmm(m, b: jnp.ndarray, *, format: str, backend: str = "jax",
         ctx: Optional[KernelContext] = None) -> jnp.ndarray:
    """One-call registry entry point: prepare + run in one shot.

    For repeated execution against one matrix, use
    ``repro.sparse.dispatch`` (cached layouts) or ``spec.bind``.
    """
    spec = get(format, backend)
    return spec.bind(m, ctx or KernelContext())(b)


# ------------------------------------------------------------------ #
# Layout helpers (host-side, shared by specs and the ops compat layer)
# ------------------------------------------------------------------ #

def pad_empty_block_rows(a):
    """Ensure every block row owns >= 1 block (zero block on the diagonal).

    The Pallas kernel writes a C tile only when its block row is visited;
    padding guarantees total coverage without in-kernel masking.
    """
    from repro.sparse.formats import BCSRMatrix
    nb = a.nb
    present = np.zeros(nb, dtype=bool)
    rows_np = np.asarray(a.block_rows)
    present[rows_np] = True
    missing = np.nonzero(~present)[0].astype(np.int32)
    if missing.size == 0:
        return a
    blocks = jnp.concatenate(
        [a.blocks, jnp.zeros((missing.size, a.t, a.t), a.blocks.dtype)])
    rows = np.concatenate([rows_np, missing])
    cols = np.concatenate([np.asarray(a.block_cols), missing])
    order = np.argsort(rows, kind="stable")
    counts = np.bincount(rows, minlength=nb)
    ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return BCSRMatrix(
        blocks=blocks[jnp.asarray(order)],
        block_rows=jnp.asarray(rows[order].astype(np.int32)),
        block_cols=jnp.asarray(cols[order].astype(np.int32)),
        block_ptr=jnp.asarray(ptr),
        n=a.n, t=a.t, nnz=a.nnz,
    )


def band_to_blocks(dia_data: np.ndarray, offsets, *, n: int, t: int):
    """Convert DIA storage to the banded kernel's block-band tensor.

    Args:
        dia_data: DIA values, [num_offsets, n] indexed by row.
        offsets: diagonal offsets matching ``dia_data`` rows.
        n: matrix dimension; t must divide n for the kernel grid.
        t: block edge of the band tensor.

    Returns:
        ``(band, w)``: band tensor [nb, 2w+1, t, t] (nb = n / t) and the
        block half-bandwidth w, as consumed by the banded kernel.
    """
    nb = (n + t - 1) // t
    max_off = max(abs(int(o)) for o in offsets) if len(offsets) else 0
    w = (max_off + t - 1) // t
    band = np.zeros((nb, 2 * w + 1, t, t), dtype=np.asarray(dia_data).dtype)
    dia = np.asarray(dia_data)
    for oi, off in enumerate(offsets):
        off = int(off)
        for r in range(n):
            c = r + off
            if 0 <= c < n and dia[oi, r] != 0:
                bi, bj = r // t, c // t
                band[bi, bj - bi + w, r % t, c % t] = dia[oi, r]
    return jnp.asarray(band), w


# ------------------------------------------------------------------ #
# Roofline estimates
# ------------------------------------------------------------------ #

@dataclasses.dataclass(frozen=True)
class KernelRoofline:
    """Sparsity-aware placement of one kernel launch on a roofline."""

    name: str
    ai: float
    useful_flops: float
    mxu_flops: float
    attainable_flops_per_s: float
    mxu_utilization: float


def csr_kernel_roofline(a, d: int, *, regime: str = "random",
                        hw: HardwareSpec = TPU_V5E) -> KernelRoofline:
    """Place a CSR kernel launch on the roofline under its regime model.

    The CSR kernel issues exactly the useful FLOPs (padding slots multiply
    zeros, a negligible <1/chunk overhead), so MXU utilization is reported
    as 1.0; what varies with structure is the B-traffic term of the AI.
    """
    tb = sm.arithmetic_intensity(regime, a.n, a.nnz, d,
                                 sizeof_val=a.data.dtype.itemsize)
    return KernelRoofline(
        name="csr_spmm", ai=tb.ai, useful_flops=tb.flops,
        mxu_flops=tb.flops,
        attainable_flops_per_s=hw.attainable(tb.ai),
        mxu_utilization=1.0)


def bcsr_kernel_roofline(a, d: int,
                         hw: HardwareSpec = TPU_V5E) -> KernelRoofline:
    """Apply the TPU blocked model (DESIGN.md Section 3) to a launch."""
    tb = sm.ai_blocked_tpu(a.n, a.nnz, d, t=a.t, num_blocks=a.num_blocks,
                           sizeof_val=a.blocks.dtype.itemsize)
    util = sm.mxu_utilization(a.nnz, a.t, a.num_blocks)
    return KernelRoofline(
        name="bcsr_spmm", ai=tb.ai, useful_flops=tb.flops,
        mxu_flops=2.0 * d * a.t * a.t * a.num_blocks,
        attainable_flops_per_s=hw.attainable(tb.ai),
        mxu_utilization=util)


def dia_kernel_roofline(m, d: int,
                        hw: HardwareSpec = TPU_V5E) -> KernelRoofline:
    """Diagonal-regime placement: B streamed once, k full diagonals issued."""
    k = max(int(np.unique(m.cols.astype(np.int64) - m.rows).shape[0]), 1)
    tb = sm.arithmetic_intensity("diagonal", m.n, m.nnz, d)
    return KernelRoofline(
        name="banded_spmm", ai=tb.ai, useful_flops=tb.flops,
        mxu_flops=2.0 * d * k * m.n,
        attainable_flops_per_s=hw.attainable(tb.ai),
        mxu_utilization=m.nnz / float(k * m.n))


def grouped_matmul_roofline(T: int, K: int, N: int, E: int, *,
                            itemsize: int = 2,
                            hw: HardwareSpec = TPU_V5E) -> KernelRoofline:
    """Block-diagonal case: every block dense => MXU utilization 1.0."""
    flops = 2.0 * T * K * N
    bytes_moved = itemsize * (T * K + E * K * N + T * N)
    ai = flops / bytes_moved
    return KernelRoofline(
        name="grouped_matmul", ai=ai, useful_flops=flops, mxu_flops=flops,
        attainable_flops_per_s=hw.attainable(ai), mxu_utilization=1.0)


# ------------------------------------------------------------------ #
# Spec implementations
# ------------------------------------------------------------------ #

def _convert(ctx: KernelContext, m, format: str):
    """Convert ``m`` to ``format``'s container, honoring ``ctx.convert``
    (the caller's conversion cache, already bound to the precision) when
    provided; the direct path packs values at the precision's dtype."""
    if ctx.convert is not None:
        return ctx.convert(m, format)
    from repro.sparse import formats as fmt
    dtype = ctx.precision.value_jnp
    if format == "csr":
        return fmt.coo_to_csr(m, dtype=dtype)
    if format == "ell":
        return fmt.coo_to_ell(m, dtype=dtype)
    if format == "bcsr":
        return fmt.coo_to_bcsr(m, ctx.bcsr_block, dtype=dtype)
    if format == "dia":
        return fmt.coo_to_dia(m, dtype=dtype,
                              max_offsets=ctx.max_dia_offsets)
    if format == "binned":
        return fmt.coo_to_binned(m, dtype=dtype)
    if format == "rowsplit":
        return fmt.coo_to_rowsplit(m, dtype=dtype, chunk=ctx.chunk)
    if format == "ell_coo":
        return fmt.coo_to_ell_coo(m, dtype=dtype)
    raise ValueError(f"unknown format {format!r}")


# ------------------------------------------------------------------ #
# Layout statistics shared by the estimates and the dispatch models
# ------------------------------------------------------------------ #

def binned_layout_stats(m, *, slab_rows: int,
                        row_tile: int = 8) -> Tuple[int, int]:
    """(slabs_touched, num_visits) of the slab-binned layout for ``m``.

    A visit is one (B slab, row tile) pair with nonzeros — the unit the
    binned kernel writes one partial C block for.  Both counts feed
    ``sm.ai_binned``: B is read once per touched slab, partials cost
    ``2 * num_visits * row_tile * d`` extra C traffic.
    """
    if m.nnz == 0:
        return 1, 1
    slabs = np.asarray(m.cols, dtype=np.int64) // slab_rows
    tiles = np.asarray(m.rows, dtype=np.int64) // row_tile
    num_slabs = max(1, -(-m.n // slab_rows))
    visits = np.unique(tiles * num_slabs + slabs).shape[0]
    return int(np.unique(slabs).shape[0]), int(visits)


def rowsplit_window_model(n_nonempty: int, nnz: int,
                          chunk: int = 128) -> int:
    """Expected row-window width of the row-split packing (model side).

    A chunk of ``chunk`` nonzeros spans ~``chunk / avg_degree`` rows;
    rounded up to the kernel's multiple-of-8 output tile.  The packed
    layout computes the exact maximum; the model uses this expectation
    so planning never needs the layout.
    """
    if nnz <= 0 or n_nonempty <= 0:
        return 8
    span = min(chunk, -(-n_nonempty * chunk // nnz) + 1)
    return max(8, -(-span // 8) * 8)


def ell_coo_split_stats(m) -> Tuple[int, int]:
    """(k_cut, tail_nnz) of the hybrid ELL/COO layout for ``m``."""
    from repro.sparse import formats as fmt
    if m.nnz == 0:
        return 1, 0
    deg = np.bincount(np.asarray(m.rows), minlength=m.n)
    k_cut = fmt.ell_coo_cutoff(deg)
    return k_cut, int(np.maximum(deg - k_cut, 0).sum())


def _jax_prepare(format: str):
    def prepare(m, ctx: KernelContext):
        return _convert(ctx, m, format)
    return prepare


def _jax_run(format: str):
    def run(layout, b, ctx: KernelContext):
        # NB: any attribute-style import of repro.sparse.spmm grabs the
        # dispatcher's spmm *function* exported by the package __init__,
        # which shadows the submodule; go through importlib.
        jax_spmm = importlib.import_module("repro.sparse.spmm")
        if ctx.precision.reduced:
            # Reduced precision: B rounds to the storage dtype (the
            # container values already are); accumulation stays fp32
            # inside the implementations.
            b = b.astype(ctx.precision.value_jnp)
        return jax_spmm.IMPLEMENTATIONS[format](layout, b)
    return run


def _jax_estimate(format: str):
    regime = {"csr": "random", "ell": "random", "dia": "diagonal"}

    def estimate(m, d, ctx: KernelContext) -> KernelRoofline:
        if format == "bcsr":
            roof = _bcsr_estimate(m, d, ctx)
            return dataclasses.replace(roof, name="bcsr_spmm_jax")
        tb = sm.arithmetic_intensity(regime[format], m.n, m.nnz, d)
        return KernelRoofline(
            name=f"{format}_spmm_jax", ai=tb.ai, useful_flops=tb.flops,
            mxu_flops=tb.flops,
            attainable_flops_per_s=ctx.hardware.attainable(tb.ai),
            mxu_utilization=1.0)
    return estimate


def _zero_footprint(n: int, d: int, ctx: KernelContext) -> int:
    return 0


#: jax-backend containers keep int32 global indices (the XLA gather
#: operand), so the jax specs support bf16 values but not compact
#: indices; the Pallas packers store slab-/chunk-local indices and add
#: int16.
_JAX_PRECISIONS = ("f32i32", "bf16i32")
_PALLAS_STREAM_PRECISIONS = ("f32i32", "bf16i32", "bf16i16")

for _f, _desc in (("csr", "gather + segment-sum (XLA)"),
                  ("ell", "padded slot scan (XLA)"),
                  ("bcsr", "batched dense-block einsum (XLA)"),
                  ("dia", "static shifted axpy (XLA)")):
    register(KernelSpec(
        format=_f, backend="jax", description=_desc,
        prepare=_jax_prepare(_f), run=_jax_run(_f),
        estimate=_jax_estimate(_f), vmem_footprint=_zero_footprint,
        supported_precisions=_JAX_PRECISIONS))


def _binned_estimate(name: str, resolve_slab):
    def estimate(m, d, ctx: KernelContext) -> KernelRoofline:
        slab = resolve_slab(m, ctx)
        touched, visits = binned_layout_stats(m, slab_rows=slab,
                                              row_tile=ctx.row_tile)
        tb = sm.ai_binned(m.n, m.nnz, d, slab_rows=slab,
                          slabs_touched=touched, num_visits=visits,
                          row_tile=ctx.row_tile)
        return KernelRoofline(
            name=name, ai=tb.ai, useful_flops=tb.flops, mxu_flops=tb.flops,
            attainable_flops_per_s=ctx.hardware.attainable(tb.ai),
            mxu_utilization=1.0)
    return estimate


def _jax_slab(m, ctx: KernelContext) -> int:
    from repro.sparse import formats as fmt
    return fmt.default_slab_rows(m.n)


def _pallas_slab(m, ctx: KernelContext) -> int:
    return ctx.resolve_b_tile(m.n) or m.n


def _rowsplit_estimate(name: str):
    def estimate(m, d, ctx: KernelContext) -> KernelRoofline:
        n_nonempty = int(np.unique(np.asarray(m.rows)).shape[0])
        window = rowsplit_window_model(n_nonempty, m.nnz, ctx.chunk)
        tb = sm.ai_rowsplit(m.n, m.nnz, d, window=window, chunk=ctx.chunk)
        return KernelRoofline(
            name=name, ai=tb.ai, useful_flops=tb.flops, mxu_flops=tb.flops,
            attainable_flops_per_s=ctx.hardware.attainable(tb.ai),
            mxu_utilization=1.0)
    return estimate


def _ell_coo_estimate(name: str):
    def estimate(m, d, ctx: KernelContext) -> KernelRoofline:
        k_cut, tail = ell_coo_split_stats(m)
        tb = sm.ai_ell_coo(m.n, m.nnz, d, k_cut=k_cut, tail_nnz=tail)
        issued = max(m.n * k_cut + tail, 1)
        return KernelRoofline(
            name=name, ai=tb.ai, useful_flops=tb.flops,
            mxu_flops=2.0 * d * issued,
            attainable_flops_per_s=ctx.hardware.attainable(tb.ai),
            mxu_utilization=min(1.0, m.nnz / issued))
    return estimate


for _f, _desc, _est in (
        ("binned", "slab-binned gather + segment-sum (XLA)",
         _binned_estimate("binned_spmm_jax", _jax_slab)),
        ("rowsplit", "equal-nnz chunk gather + segment-sum (XLA)",
         _rowsplit_estimate("rowsplit_spmm_jax")),
        ("ell_coo", "padded-body slot scan + COO-tail segment-sum (XLA)",
         _ell_coo_estimate("ell_coo_spmm_jax"))):
    register(KernelSpec(
        format=_f, backend="jax", description=_desc,
        prepare=_jax_prepare(_f), run=_jax_run(_f),
        estimate=_est, vmem_footprint=_zero_footprint,
        supported_precisions=_JAX_PRECISIONS))


def _csr_pallas_prepare(m, ctx: KernelContext):
    csr = _convert(ctx, m, "csr")
    bt = ctx.resolve_b_tile(m.n)
    tiles, slabs, cols, slots, vals = csr_to_row_tiles(
        np.asarray(csr.indptr), np.asarray(csr.indices),
        np.asarray(csr.data), n=csr.n, row_tile=ctx.row_tile,
        chunk=ctx.chunk, b_tile=bt,
        index_dtype=ctx.precision.index_np)
    return {"n": csr.n, "b_tile": bt, "row_tile": ctx.row_tile,
            "arrays": tuple(jnp.asarray(x)
                            for x in (tiles, slabs, cols, slots, vals))}


def _csr_pallas_run(layout, b, ctx: KernelContext):
    tiles, slabs, cols, slots, vals = layout["arrays"]
    if ctx.precision.reduced:
        b = b.astype(ctx.precision.value_jnp)
    return csr_spmm_pallas(
        tiles, slabs, cols, slots, vals, b, n=layout["n"],
        row_tile=layout["row_tile"], b_tile=layout["b_tile"],
        block_d=pallas_block_d(b.shape[1]),
        interpret=ctx.resolve_interpret())


def _csr_pallas_estimate(m, d, ctx: KernelContext) -> KernelRoofline:
    tb = sm.arithmetic_intensity("random", m.n, m.nnz, d)
    return KernelRoofline(
        name="csr_spmm", ai=tb.ai, useful_flops=tb.flops, mxu_flops=tb.flops,
        attainable_flops_per_s=ctx.hardware.attainable(tb.ai),
        mxu_utilization=1.0)


def _csr_pallas_footprint(n: int, d: int, ctx: KernelContext) -> int:
    bd = min(512, pallas_block_d(d))
    bt = ctx.resolve_b_tile(n) or n
    sv = ctx.precision.sizeof_val
    si = ctx.precision.sizeof_idx
    # Resident: B slab + gathered chunk + vals chunk at the value width,
    # cols/slots chunks at the index width, C tile always fp32 (the VMEM
    # accumulator keeps full precision regardless of operand dtype).
    return (sv * (bt * bd + ctx.chunk * bd + ctx.chunk)
            + si * 2 * ctx.chunk + 4 * ctx.row_tile * bd)


for _f in ("csr", "ell"):
    # ELL exists for VPU-style padding; the row-tiled CSR kernel already
    # vectorizes on TPU, so ELL picks lower to it (layout_key="csr":
    # both specs share one cached row-tile packing per matrix).
    register(KernelSpec(
        format=_f, backend="pallas",
        description="row-tiled gather/segment-sum kernel, B streamed by "
                    "VMEM-sized row slabs",
        prepare=_csr_pallas_prepare, run=_csr_pallas_run,
        estimate=_csr_pallas_estimate, vmem_footprint=_csr_pallas_footprint,
        layout_key="csr", supported_precisions=_PALLAS_STREAM_PRECISIONS))


def _binned_pallas_prepare(m, ctx: KernelContext):
    csr = _convert(ctx, m, "csr")
    bt = ctx.resolve_b_tile(m.n)
    arrays = csr_to_slab_bins(
        np.asarray(csr.indptr), np.asarray(csr.indices),
        np.asarray(csr.data), n=csr.n, row_tile=ctx.row_tile,
        chunk=ctx.chunk, b_tile=bt,
        index_dtype=ctx.precision.index_np)
    return {"n": csr.n, "b_tile": bt, "row_tile": ctx.row_tile,
            "arrays": tuple(jnp.asarray(x) for x in arrays)}


def _binned_pallas_run(layout, b, ctx: KernelContext):
    vt, cv, cs, cols, slots, vals = layout["arrays"]
    if ctx.precision.reduced:
        b = b.astype(ctx.precision.value_jnp)
    return binned_spmm_pallas(
        vt, cv, cs, cols, slots, vals, b, n=layout["n"],
        row_tile=layout["row_tile"], b_tile=layout["b_tile"],
        block_d=pallas_block_d(b.shape[1]),
        interpret=ctx.resolve_interpret())


register(KernelSpec(
    format="binned", backend="pallas",
    description="two-phase binned kernel: slab-major accumulation over "
                "VMEM-resident B slabs, segment-sum epilogue",
    prepare=_binned_pallas_prepare, run=_binned_pallas_run,
    estimate=_binned_estimate("binned_spmm", _pallas_slab),
    # Residency matches the streamed CSR kernel: one B slab, one partial
    # C block, and the gather/index chunks (the visit partials live in
    # HBM and stream through the same C-tile slot).
    vmem_footprint=_csr_pallas_footprint,
    layout_key="binned", supported_precisions=_PALLAS_STREAM_PRECISIONS))


def _rowsplit_pallas_prepare(m, ctx: KernelContext):
    csr = _convert(ctx, m, "csr")
    row_map, cols, slots, vals = pack_rowsplit_chunks(
        np.asarray(csr.indptr), np.asarray(csr.indices),
        np.asarray(csr.data), n=csr.n, chunk=ctx.chunk,
        index_dtype=ctx.precision.index_np)
    return {"n": csr.n, "window": int(row_map.shape[1]),
            "arrays": tuple(jnp.asarray(x)
                            for x in (row_map, cols, slots, vals))}


def _rowsplit_pallas_run(layout, b, ctx: KernelContext):
    row_map, cols, slots, vals = layout["arrays"]
    if ctx.precision.reduced:
        b = b.astype(ctx.precision.value_jnp)
    return rowsplit_spmm_pallas(
        row_map, cols, slots, vals, b, n=layout["n"],
        window=layout["window"], block_d=pallas_block_d(b.shape[1]),
        interpret=ctx.resolve_interpret())


def _rowsplit_pallas_footprint(n: int, d: int, ctx: KernelContext) -> int:
    bd = min(512, pallas_block_d(d))
    n_pad = -(-n // 8) * 8
    sv = ctx.precision.sizeof_val
    si = ctx.precision.sizeof_idx
    # Whole B resident (the load-balance kernel does not stream B) plus
    # the gather chunk and vals at the value width, cols/slots at the
    # index width, and the fp32 window partial.
    return (sv * (n_pad * bd + ctx.chunk * bd + ctx.chunk)
            + si * 2 * ctx.chunk + 4 * ctx.chunk * bd)


register(KernelSpec(
    format="rowsplit", backend="pallas",
    description="equal-nnz row-split kernel (merge-path load balance), "
                "windowed partials + scatter epilogue",
    prepare=_rowsplit_pallas_prepare, run=_rowsplit_pallas_run,
    estimate=_rowsplit_estimate("rowsplit_spmm"),
    vmem_footprint=_rowsplit_pallas_footprint,
    layout_key="rowsplit", supported_precisions=_PALLAS_STREAM_PRECISIONS))


# The hybrid ELL/COO pick lowers to the row-tiled CSR kernel on TPU
# (like ELL): the CSR kernel's sliced-ELL chunk packing already realizes
# the body/tail split physically — short rows pack densely, hub-row
# overflow lands in extra chunks — so the pallas pair shares the cached
# CSR row-tile layout and differs only in its estimate.
register(KernelSpec(
    format="ell_coo", backend="pallas",
    description="hybrid ELL/COO pick lowered to the row-tiled CSR kernel",
    prepare=_csr_pallas_prepare, run=_csr_pallas_run,
    estimate=_ell_coo_estimate("ell_coo_spmm"),
    vmem_footprint=_csr_pallas_footprint,
    layout_key="csr", supported_precisions=_PALLAS_STREAM_PRECISIONS))


def _bcsr_pallas_prepare(m, ctx: KernelContext):
    return pad_empty_block_rows(_convert(ctx, m, "bcsr"))


def _bcsr_pallas_run(layout, b, ctx: KernelContext):
    if ctx.precision.reduced:
        b = b.astype(ctx.precision.value_jnp)
    return bcsr_spmm_pallas(
        layout.blocks, layout.block_rows, layout.block_cols, b,
        n=layout.n, t=layout.t, block_d=pallas_block_d(b.shape[1]),
        interpret=ctx.resolve_interpret())


def _bcsr_estimate(m, d, ctx: KernelContext) -> KernelRoofline:
    from repro.core.classify import block_stats
    t = ctx.bcsr_block
    stats = block_stats(m, t)
    N = max(int(stats["N"]), 1)
    tb = sm.ai_blocked_tpu(m.n, m.nnz, d, t=t, num_blocks=N)
    return KernelRoofline(
        name="bcsr_spmm", ai=tb.ai, useful_flops=tb.flops,
        mxu_flops=2.0 * d * t * t * N,
        attainable_flops_per_s=ctx.hardware.attainable(tb.ai),
        mxu_utilization=sm.mxu_utilization(m.nnz, t, N))


def _bcsr_pallas_footprint(n: int, d: int, ctx: KernelContext) -> int:
    t, bd = ctx.bcsr_block, min(512, pallas_block_d(d))
    # Block + B tile at the value width; the C tile accumulates in fp32.
    sv = ctx.precision.sizeof_val
    return sv * (t * t + t * bd) + 4 * t * bd


register(KernelSpec(
    format="bcsr", backend="pallas",
    description="dense-block MXU kernel (scalar-prefetch block walk)",
    prepare=_bcsr_pallas_prepare, run=_bcsr_pallas_run,
    estimate=_bcsr_estimate, vmem_footprint=_bcsr_pallas_footprint,
    # Block coordinates are scalar-prefetch metadata, not per-nonzero
    # traffic, so bcsr gains nothing from int16 and keeps int32.
    supported_precisions=_JAX_PRECISIONS))


def _dia_pallas_prepare(m, ctx: KernelContext):
    dia = _convert(ctx, m, "dia")
    t = pallas_band_tile(m.n)
    band, w = band_to_blocks(np.asarray(dia.data), dia.offsets, n=m.n, t=t)
    return {"band": band, "w": w, "t": t}


def _dia_pallas_run(layout, b, ctx: KernelContext):
    if ctx.precision.reduced:
        b = b.astype(ctx.precision.value_jnp)
    return banded_spmm_pallas(
        layout["band"], b, t=layout["t"], w=layout["w"],
        block_d=pallas_block_d(b.shape[1]),
        interpret=ctx.resolve_interpret())


def _dia_pallas_estimate(m, d, ctx: KernelContext) -> KernelRoofline:
    return dia_kernel_roofline(m, d, hw=ctx.hardware)


def _dia_pallas_footprint(n: int, d: int, ctx: KernelContext) -> int:
    t, bd = pallas_band_tile(n), min(512, pallas_block_d(d))
    sv = ctx.precision.sizeof_val
    return sv * (t * t + t * bd) + 4 * t * bd


register(KernelSpec(
    format="dia", backend="pallas",
    description="block-band kernel (B streamed once)",
    prepare=_dia_pallas_prepare, run=_dia_pallas_run,
    estimate=_dia_pallas_estimate, vmem_footprint=_dia_pallas_footprint,
    # DIA stores no per-nonzero indices at all (offsets are static), so
    # the index axis is moot; bf16 values still halve the band traffic.
    supported_precisions=_JAX_PRECISIONS))


def _grouped_prepare(operand, ctx: KernelContext):
    # Operand: (w[E, K, N], group_ids[T // bm], bm, bk, bn).
    return operand


def _grouped_run(layout, x, ctx: KernelContext):
    w, group_ids, bm, bk, bn = layout
    return grouped_matmul_pallas(x, w, group_ids, bm=bm, bk=bk, bn=bn,
                                 interpret=ctx.resolve_interpret())


def _grouped_estimate(operand, d, ctx: KernelContext) -> KernelRoofline:
    w, group_ids, bm, _, _ = operand
    E, K, N = w.shape
    T = int(np.asarray(group_ids).shape[0]) * bm
    return grouped_matmul_roofline(T, K, N, E, hw=ctx.hardware)


def _grouped_footprint(n: int, d: int, ctx: KernelContext) -> int:
    bm = bk = bn = 128
    return 4 * (bm * bk + bk * bn + bm * bn)


register(KernelSpec(
    format="grouped", backend="pallas",
    description="MoE expert FFN as block-diagonal grouped matmul",
    prepare=_grouped_prepare, run=_grouped_run,
    estimate=_grouped_estimate, vmem_footprint=_grouped_footprint,
    operand="moe"))
