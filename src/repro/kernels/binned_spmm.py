"""Pallas TPU kernels for the scale-free regime: binned + row-split SpMM.

Two layouts over the same gather/one-hot-matmul machinery as the CSR
kernel (``repro.kernels.csr_spmm``), each attacking one failure mode of
slab-streamed CSR on power-law matrices:

Two-phase binned SpMM (propagation blocking, Gu et al. 2020)
    Phase one (host, ``csr_to_slab_bins``) bins nonzeros by the B row
    slab they gather from and orders them CSC-like (by column) inside
    each slab — the standalone bin layout the CSR kernel's per-tile slab
    grouping hinted at.  Phase two visits slabs in order: while one
    ``[b_tile, bd]`` slab of B is VMEM resident, *every* row tile with
    nonzeros in that slab accumulates its contribution into a private
    partial-C block.  B is read once per touched slab per d-pass
    (streaming writes of partials) instead of once per nonzero
    (streaming gathers); a segment-sum epilogue folds the per-visit
    partials into C.  On skewed matrices hub columns concentrate
    nonzeros into few slabs, so the slab reads amortize across many
    more nonzeros than CSR's tile-local slab runs.

Row-split SpMM (merge-path style load balancing)
    The row-major nonzero stream is cut into chunks of exactly ``chunk``
    entries regardless of row boundaries, so a hub row spans many grid
    steps instead of serializing one row tile.  Because the stream is
    row-major, the distinct rows inside one chunk form a contiguous run
    of nonempty-row ranks; the kernel reduces each chunk into a
    ``[window, bd]`` partial via the one-hot matmul, and a segment-sum
    epilogue scatters windows back to global rows through a host-built
    ``row_map``.  Total padding is under one chunk for the whole matrix
    (CSR tiling pays up to one chunk per (tile, slab) pair).

Both kernels visit every output block in one contiguous run of grid
steps (the binned kernel zeroes on visit change exactly like the CSR
kernel zeroes on tile change), so no block is revisited after another
block was written — the same output-visitation contract the existing
kernels rely on.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.csr_spmm import _csr_kernel, index_extent_check


def csr_to_slab_bins(indptr: np.ndarray, indices: np.ndarray,
                     data: np.ndarray, *, n: int, row_tile: int = 8,
                     chunk: int = 128, b_tile: Optional[int] = None,
                     index_dtype=np.int32
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray, np.ndarray]:
    """Bin CSR nonzeros by B row slab (phase one of the binned kernel).

    Returns ``(visit_tiles[V], chunk_visits[C], chunk_slabs[C],
    cols[C, chunk], row_slots[C, chunk], vals[C, chunk])``.  A *visit* is
    one (slab, row-tile) pair with nonzeros; its chunks are contiguous
    and visits are ordered slab-major, so each B slab is resident for
    one contiguous run of grid steps per d-pass.  Within a visit,
    entries are sorted by column (CSC-like inside the slab), ``cols``
    are slab-local, and ``row_slots`` are row indices within the tile.

    ``visit_tiles`` maps each visit to its row tile for the segment-sum
    epilogue.  With ``b_tile=None`` there is a single slab spanning all
    rows (the layout degenerates to one visit per nonempty row tile).
    An empty matrix still produces one all-zero visit so the kernel has
    a well-formed grid.

    ``cols``/``row_slots`` are stored at ``index_dtype``: slab-local
    columns address at most ``b_tile`` rows, so int16 is legal whenever
    the slab height fits (the kernel upcasts after the VMEM load).
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices).astype(np.int64)
    data = np.asarray(data)
    index_extent_check(n if b_tile is None else b_tile, index_dtype)
    nnz = int(indptr[-1])
    rows = np.repeat(np.arange(n, dtype=np.int64),
                     np.diff(indptr).astype(np.int64))
    cols = indices[:nnz]
    vals = data[:nnz]
    bt = n if b_tile is None else b_tile
    slabs = cols // bt
    tiles = rows // row_tile
    # The binning pass: slab-major, then row tile, then column (CSC-like
    # within each slab).  lexsort keys are last-key-major.
    order = np.lexsort((rows, cols, tiles, slabs))
    rows, cols, vals = rows[order], cols[order], vals[order]
    slabs, tiles = slabs[order], tiles[order]

    visit_tiles, chunk_visits, chunk_slabs = [], [], []
    cols_c, slots_c, vals_c = [], [], []

    def emit(tile: int, slab: int, seg_cols: np.ndarray,
             seg_slots: np.ndarray, seg_vals: np.ndarray) -> None:
        cnt = seg_cols.shape[0]
        n_chunks = max(1, -(-cnt // chunk))
        c = np.zeros(n_chunks * chunk, dtype=index_dtype)
        s = np.zeros(n_chunks * chunk, dtype=index_dtype)
        v = np.zeros(n_chunks * chunk, dtype=data.dtype)
        c[:cnt] = seg_cols
        s[:cnt] = seg_slots
        v[:cnt] = seg_vals
        visit = len(visit_tiles)
        visit_tiles.append(tile)
        chunk_visits.extend([visit] * n_chunks)
        chunk_slabs.extend([slab] * n_chunks)
        cols_c.append(c.reshape(n_chunks, chunk))
        slots_c.append(s.reshape(n_chunks, chunk))
        vals_c.append(v.reshape(n_chunks, chunk))

    if nnz == 0:
        emit(0, 0, np.zeros(0, np.int32), np.zeros(0, np.int32),
             np.zeros(0, data.dtype))
    else:
        keys = slabs * ((n + row_tile - 1) // row_tile + 1) + tiles
        bounds = np.flatnonzero(np.diff(keys)) + 1
        for seg in zip(np.split(rows, bounds), np.split(cols, bounds),
                       np.split(vals, bounds), np.split(slabs, bounds),
                       np.split(tiles, bounds)):
            seg_rows, seg_cols, seg_vals, seg_slabs, seg_tiles = seg
            tile = int(seg_tiles[0])
            slab = int(seg_slabs[0])
            emit(tile, slab,
                 (seg_cols - slab * bt).astype(index_dtype),
                 (seg_rows - tile * row_tile).astype(index_dtype), seg_vals)
    return (np.asarray(visit_tiles, dtype=np.int32),
            np.asarray(chunk_visits, dtype=np.int32),
            np.asarray(chunk_slabs, dtype=np.int32),
            np.concatenate(cols_c), np.concatenate(slots_c),
            np.concatenate(vals_c))


@functools.partial(jax.jit,
                   static_argnames=("n", "row_tile", "b_tile", "block_d",
                                    "interpret"))
def binned_spmm_pallas(visit_tiles: jnp.ndarray, chunk_visits: jnp.ndarray,
                       chunk_slabs: jnp.ndarray, cols: jnp.ndarray,
                       row_slots: jnp.ndarray, vals: jnp.ndarray,
                       b: jnp.ndarray, *, n: int, row_tile: int = 8,
                       b_tile: Optional[int] = None, block_d: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """C = A @ B with A given as slab-binned chunks (csr_to_slab_bins).

    The grid walks chunks slab-major; the chunk body is exactly the CSR
    kernel's (gather from the resident slab, scale, one-hot matmul),
    but the output block is the *visit*'s private partial, zeroed on
    visit change and owned for one contiguous run.  The epilogue
    segment-sums partials by ``visit_tiles`` into the row tiles —
    that reduction (2 * V * row_tile * d extra C traffic) is the price
    the binned AI model charges for reading B once per touched slab.

    Args:
      visit_tiles:  [V] int32 row-tile id per visit.
      chunk_visits: [C] int32 visit id per chunk (non-decreasing).
      chunk_slabs:  [C] int32 B row-slab id per chunk (non-decreasing).
      cols:         [C, chunk] int32 slab-local columns, zero-padded.
      row_slots:    [C, chunk] int32 row index within the tile.
      vals:         [C, chunk] values, zero-padded.
      b:            [n, d] dense operand.
      n:            matrix dimension (static).
      row_tile:     rows per C tile (static).
      b_tile:       B rows per VMEM-resident slab (static); must match
                    the layout's ``b_tile``.  None holds B whole.
      block_d:      d-tile width (static).
      interpret:    run in interpret mode (CPU correctness path).
    """
    d = b.shape[1]
    bd = min(block_d, d)
    if d % bd != 0:
        raise ValueError(f"d={d} must be divisible by the d-tile {bd}")
    bt = b.shape[0] if b_tile is None else b_tile
    if b.shape[0] % bt != 0:
        pad = bt - b.shape[0] % bt
        b = jnp.concatenate([b, jnp.zeros((pad, d), b.dtype)])
    num_chunks, chunk = cols.shape
    num_visits = visit_tiles.shape[0]
    num_tiles = (n + row_tile - 1) // row_tile
    grid = (d // bd, num_chunks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk),
                         lambda i_d, i_c, visits, slabs: (i_c, 0)),
            pl.BlockSpec((1, chunk),
                         lambda i_d, i_c, visits, slabs: (i_c, 0)),
            pl.BlockSpec((1, chunk),
                         lambda i_d, i_c, visits, slabs: (i_c, 0)),
            pl.BlockSpec((bt, bd),
                         lambda i_d, i_c, visits, slabs: (slabs[i_c], i_d)),
        ],
        out_specs=pl.BlockSpec(
            (row_tile, bd),
            lambda i_d, i_c, visits, slabs: (visits[i_c], i_d)),
    )
    # The chunk body is the CSR kernel's, with visit ids in the tile-id
    # slot: "zero on owner change, accumulate" is the same contract.
    partials = pl.pallas_call(
        functools.partial(_csr_kernel, row_tile=row_tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_visits * row_tile, d),
                                       jnp.float32),
        interpret=interpret,
    )(chunk_visits, chunk_slabs, cols, row_slots, vals, b)
    # Epilogue: fold visit partials into their row tiles.
    tiled = jax.ops.segment_sum(
        partials.reshape(num_visits, row_tile, d), visit_tiles,
        num_segments=num_tiles)
    return tiled.reshape(num_tiles * row_tile, d)[:n].astype(b.dtype)


def pack_rowsplit_chunks(indptr: np.ndarray, indices: np.ndarray,
                         data: np.ndarray, *, n: int, chunk: int = 128,
                         index_dtype=np.int32
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]:
    """Cut the row-major nonzero stream into equal-``chunk`` work units.

    Returns ``(row_map[C, W], cols[C, chunk], row_slots[C, chunk],
    vals[C, chunk])``.  ``row_slots`` index into a per-chunk window of
    ``W`` rows: because the stream is row-major, the distinct rows of a
    chunk are consecutive nonempty-row ranks, so slot ``w`` of chunk
    ``c`` is global row ``row_map[c, w]`` (or the sentinel ``n`` past
    the window's last real row).  ``W`` is the widest chunk's row span,
    rounded up to a multiple of 8 for the output tile.

    Unlike the CSR packing there is no per-(tile, slab) padding: total
    padding is under one chunk regardless of degree skew.

    ``cols``/``row_slots`` are stored at ``index_dtype``.  Row-split
    columns are *global* (the kernel holds all of B resident), so int16
    is only legal when ``n`` itself fits — checked here; ``row_map``
    stays int32 (it is epilogue metadata, not per-nonzero traffic).
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    data = np.asarray(data)
    index_extent_check(n, index_dtype)
    nnz = int(indptr[-1])
    rows = np.repeat(np.arange(n, dtype=np.int64),
                     np.diff(indptr).astype(np.int64))
    num_chunks = max(1, -(-nnz // chunk))
    padded = num_chunks * chunk
    cols_p = np.zeros(padded, dtype=index_dtype)
    vals_p = np.zeros(padded, dtype=data.dtype)
    cols_p[:nnz] = indices[:nnz]
    vals_p[:nnz] = data[:nnz]
    # Rank each nonzero's row among the nonempty rows (ascending).
    nonempty = np.flatnonzero(np.diff(indptr) > 0).astype(np.int64)
    ranks = np.searchsorted(nonempty, rows)
    ranks_p = np.zeros(padded, dtype=np.int64)
    ranks_p[:nnz] = ranks
    ranks_p[nnz:] = ranks_p[nnz - 1] if nnz else 0
    ranks_c = ranks_p.reshape(num_chunks, chunk)
    rank_lo = ranks_c[:, 0]
    slots = (ranks_c - rank_lo[:, None]).astype(index_dtype)
    span = int((slots.max() + 1)) if nnz else 1
    window = max(8, -(-span // 8) * 8)
    # Global row per (chunk, window slot); sentinel n past the last rank.
    flat = rank_lo[:, None] + np.arange(window)[None, :]
    row_map = np.where(flat < nonempty.shape[0],
                       nonempty[np.minimum(flat, nonempty.shape[0] - 1)]
                       if nonempty.shape[0] else 0,
                       n).astype(np.int32)
    if nonempty.shape[0] == 0:
        row_map[:] = n
    return (row_map, cols_p.reshape(num_chunks, chunk), slots,
            vals_p.reshape(num_chunks, chunk))


def _rowsplit_kernel(cols_ref, slots_ref, vals_ref, b_ref, o_ref, *,
                     window: int):
    """One grid step: reduce one equal-nnz chunk into its row window."""
    # int16-packed indices pay HBM/VMEM traffic at the compact width; the
    # gather wants int32, so upcast after the load.
    cols = cols_ref[0].astype(jnp.int32)             # [chunk]
    slots = slots_ref[0].astype(jnp.int32)           # [chunk]
    vals = vals_ref[0]                               # [chunk]
    gathered = b_ref[...][cols]                      # [chunk, bd]
    scaled = gathered * vals[:, None]
    rows = jax.lax.broadcasted_iota(jnp.int32, (window, cols.shape[0]), 0)
    onehot = (rows == slots[None, :]).astype(scaled.dtype)
    # Each chunk owns its window block exclusively: one write, no
    # accumulation, no zeroing predicate.
    o_ref[...] = jnp.dot(onehot, scaled, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("n", "window", "block_d", "interpret"))
def rowsplit_spmm_pallas(row_map: jnp.ndarray, cols: jnp.ndarray,
                         row_slots: jnp.ndarray, vals: jnp.ndarray,
                         b: jnp.ndarray, *, n: int, window: int,
                         block_d: int = 512,
                         interpret: bool = True) -> jnp.ndarray:
    """C = A @ B with A as equal-nnz chunks (pack_rowsplit_chunks).

    Args:
      row_map:   [C, W] int32 global row per window slot (n = sentinel).
      cols:      [C, chunk] int32 global columns, zero-padded.
      row_slots: [C, chunk] int32 window slot per nonzero.
      vals:      [C, chunk] values, zero-padded.
      b:         [n, d] dense operand (held whole; the row-split kernel
                 trades B residency for perfect load balance).
      n:         matrix dimension (static).
      window:    W, the widest chunk's row span (static, multiple of 8).
      block_d:   d-tile width (static).
      interpret: run in interpret mode (CPU correctness path).
    """
    d = b.shape[1]
    bd = min(block_d, d)
    if d % bd != 0:
        raise ValueError(f"d={d} must be divisible by the d-tile {bd}")
    if b.shape[0] % 8 != 0:
        pad = 8 - b.shape[0] % 8
        b = jnp.concatenate([b, jnp.zeros((pad, d), b.dtype)])
    num_chunks, chunk = cols.shape
    grid = (d // bd, num_chunks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i_d, i_c: (i_c, 0)),
            pl.BlockSpec((1, chunk), lambda i_d, i_c: (i_c, 0)),
            pl.BlockSpec((1, chunk), lambda i_d, i_c: (i_c, 0)),
            pl.BlockSpec((b.shape[0], bd), lambda i_d, i_c: (0, i_d)),
        ],
        out_specs=pl.BlockSpec((window, bd), lambda i_d, i_c: (i_c, i_d)),
    )
    partials = pl.pallas_call(
        functools.partial(_rowsplit_kernel, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_chunks * window, d),
                                       jnp.float32),
        interpret=interpret,
    )(cols, row_slots, vals, b)
    # Epilogue: scatter windows to global rows; sentinel n is dropped.
    out = jax.ops.segment_sum(partials, row_map.reshape(-1),
                              num_segments=n + 1)
    return out[:n].astype(b.dtype)
