"""Pure-jnp oracles for the Pallas kernels.

Each oracle reconstructs the mathematically obvious computation (densify +
matmul, or one-hot einsum) with no shared code paths with the kernels, so a
kernel bug cannot hide in a shared helper.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def csr_ref(indptr, indices, data, b, *, n: int):
    """Densify the CSR arrays on host, then one dense matmul."""
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    data = np.asarray(data, dtype=np.float64)
    dense = np.zeros((n, n), dtype=np.float64)
    for r in range(n):
        for k in range(int(indptr[r]), int(indptr[r + 1])):
            dense[r, int(indices[k])] += data[k]
    return jnp.asarray(dense @ np.asarray(b, dtype=np.float64)).astype(
        b.dtype)


def bcsr_ref(blocks, block_rows, block_cols, b, *, n: int, t: int):
    """Densify the block structure on host, then one dense matmul."""
    blocks = np.asarray(blocks)
    block_rows = np.asarray(block_rows)
    block_cols = np.asarray(block_cols)
    dense = np.zeros((n, n), dtype=np.float64)
    for blk, br, bc in zip(blocks, block_rows, block_cols):
        dense[br * t:(br + 1) * t, bc * t:(bc + 1) * t] += blk
    return jnp.asarray(dense @ np.asarray(b, dtype=np.float64)).astype(
        b.dtype)


def banded_ref(band, b, *, t: int, w: int):
    """Densify the band, then one dense matmul."""
    band = np.asarray(band)
    nb = band.shape[0]
    n = nb * t
    dense = np.zeros((n, n), dtype=np.float64)
    for i in range(nb):
        for o in range(2 * w + 1):
            j = i + o - w
            if 0 <= j < nb:
                dense[i * t:(i + 1) * t, j * t:(j + 1) * t] += band[i, o]
    return jnp.asarray(dense @ np.asarray(b, dtype=np.float64)).astype(
        b.dtype)


def grouped_matmul_ref(x, w, group_ids, *, bm: int):
    """One-hot contraction: out = einsum(x, onehot(expert_of_row), w)."""
    x_np = np.asarray(x, dtype=np.float64)
    w_np = np.asarray(w, dtype=np.float64)
    E = w_np.shape[0]
    row_groups = np.repeat(np.asarray(group_ids), bm)     # [T]
    onehot = (row_groups[:, None] == np.arange(E)[None, :]).astype(
        np.float64)                                       # [T, E]
    out = np.einsum("tk,te,ekn->tn", x_np, onehot, w_np)
    return jnp.asarray(out).astype(x.dtype)
