"""Pallas TPU kernel: CSR row-gather / segment-sum SpMM with a streamed B.

TPU realization of the paper's CSR baseline (the random-regime
implementation): every nonzero gathers its row of B and the products are
segment-summed by destination row.  The kernel tiles that traversal so the
segment sum becomes an MXU matmul:

  * rows are grouped into tiles of ``row_tile`` rows; each tile's nonzeros
    are padded to whole chunks of ``chunk`` entries (sliced-ELL style
    packing of the CSR arrays, built host-side by ``csr_to_row_tiles``);
  * one grid step processes one chunk: it gathers ``chunk`` rows of B from
    the VMEM-resident slab, scales by the nonzero values, and reduces
    into the tile's C block with a one-hot [row_tile, chunk] matmul — the
    segment-sum expressed as MXU work instead of scatter traffic;
  * chunk -> row-tile ownership arrives via scalar prefetch (like the BCSR
    kernel's block coordinates), so the C tile stays resident in VMEM for
    all chunks of a tile and is written exactly once.

B streaming (propagation-blocking style, Gu et al. 2020): the gather
targets are data-dependent, so no index map could stream B row-by-row —
but the *host* can.  ``csr_to_row_tiles`` optionally groups each row
tile's nonzeros by the B row slab they gather from (``b_tile`` rows per
slab) and records the slab id per chunk.  The kernel's B BlockSpec then
covers one ``[b_tile, bd]`` slab, selected per chunk through scalar
prefetch, and column indices are stored slab-local.  VMEM now holds one
slab instead of all of B, so the kernel scales past the old
``n * bd * 4 <= VMEM`` bound; with ``b_tile=None`` (one slab spanning all
rows) the layout and kernel reduce exactly to the unstreamed original.

Padding slots carry value 0 (and column/row-slot 0), so they contribute
nothing; every row tile owns at least one chunk, so every C block is
visited and zeroed even for empty rows.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def index_extent_check(extent: int, index_dtype) -> None:
    """Refuse an index dtype that cannot address ``extent`` positions.

    The packers reserve sentinel values equal to the extent itself, so
    the extent — not ``extent - 1`` — must be representable (an extent of
    exactly ``2**15`` is illegal for int16).
    """
    if np.dtype(index_dtype) == np.int16 and extent > 2 ** 15 - 1:
        raise ValueError(
            f"int16 indices cannot address extent {extent} "
            f"(max {2 ** 15 - 1} including the sentinel slot)")


def csr_to_row_tiles(indptr: np.ndarray, indices: np.ndarray,
                     data: np.ndarray, *, n: int, row_tile: int = 8,
                     chunk: int = 128,
                     b_tile: Optional[int] = None,
                     index_dtype=np.int32
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray]:
    """Pack CSR arrays into fixed-size chunks grouped by row tile.

    Returns ``(tile_ids[C], b_tile_ids[C], cols[C, chunk],
    row_slots[C, chunk], vals[C, chunk])`` where chunk ``c`` belongs to row
    tile ``tile_ids[c]``, gathers only from B row slab ``b_tile_ids[c]``,
    and ``row_slots`` are row indices *within* the tile.  Chunks of a tile
    are contiguous; empty tiles still get one all-zero chunk.

    With ``b_tile=None`` there is a single slab spanning all rows:
    ``b_tile_ids`` is all zeros and ``cols`` are global row indices of B.
    With ``b_tile=bt`` each row tile's nonzeros are partitioned by
    ``col // bt`` (ascending slab order) and ``cols`` become slab-local
    (``col - slab * bt``), so the kernel only needs one ``[bt, bd]`` slab
    of B resident per chunk.

    ``cols``/``row_slots`` are stored at ``index_dtype``: with slab
    streaming the addressed extent is only ``b_tile`` rows, so int16
    columns are legal whenever the slab height fits (the kernel upcasts
    after the VMEM load — traffic is paid at the compact width).
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    data = np.asarray(data)
    index_extent_check(n if b_tile is None else b_tile, index_dtype)
    num_tiles = (n + row_tile - 1) // row_tile
    tile_ids, slab_ids, cols_c, slots_c, vals_c = [], [], [], [], []

    def emit(tile: int, slab: int, cols: np.ndarray, slots: np.ndarray,
             vals: np.ndarray) -> None:
        cnt = cols.shape[0]
        n_chunks = max(1, -(-cnt // chunk))
        c = np.zeros(n_chunks * chunk, dtype=index_dtype)
        s = np.zeros(n_chunks * chunk, dtype=index_dtype)
        v = np.zeros(n_chunks * chunk, dtype=data.dtype)
        c[:cnt] = cols
        s[:cnt] = slots
        v[:cnt] = vals
        tile_ids.extend([tile] * n_chunks)
        slab_ids.extend([slab] * n_chunks)
        cols_c.append(c.reshape(n_chunks, chunk))
        slots_c.append(s.reshape(n_chunks, chunk))
        vals_c.append(v.reshape(n_chunks, chunk))

    for tile in range(num_tiles):
        r0 = tile * row_tile
        r1 = min(r0 + row_tile, n)
        lo, hi = int(indptr[r0]), int(indptr[r1])
        cols = indices[lo:hi].astype(np.int64)
        vals = data[lo:hi]
        row_of_nz = np.repeat(np.arange(r0, r1),
                              np.diff(indptr[r0:r1 + 1]).astype(np.int64))
        slots = (row_of_nz - r0).astype(index_dtype)
        if b_tile is None:
            emit(tile, 0, cols.astype(index_dtype), slots, vals)
            continue
        slabs = cols // b_tile
        if cols.shape[0] == 0:
            emit(tile, 0, cols.astype(index_dtype), slots, vals)
            continue
        # Stable partition by slab: chunks of a tile stay contiguous and
        # visit slabs in ascending order (sequential-ish B traffic).
        order = np.argsort(slabs, kind="stable")
        cols, vals, slots, slabs = (cols[order], vals[order], slots[order],
                                    slabs[order])
        bounds = np.flatnonzero(np.diff(slabs)) + 1
        for seg_cols, seg_slots, seg_vals, seg_slabs in zip(
                np.split(cols, bounds), np.split(slots, bounds),
                np.split(vals, bounds), np.split(slabs, bounds)):
            slab = int(seg_slabs[0])
            emit(tile, slab, (seg_cols - slab * b_tile).astype(index_dtype),
                 seg_slots, seg_vals)
    return (np.asarray(tile_ids, dtype=np.int32),
            np.asarray(slab_ids, dtype=np.int32),
            np.concatenate(cols_c), np.concatenate(slots_c),
            np.concatenate(vals_c))


def _csr_kernel(tiles_ref, slabs_ref, cols_ref, slots_ref, vals_ref, b_ref,
                o_ref, *, row_tile: int):
    """One grid step: gather-scale one chunk, one-hot-matmul into its C tile."""
    del slabs_ref  # consumed by the B index map
    i_c = pl.program_id(1)
    # First chunk of this row tile in this d-pass: zero the resident C block.
    is_first = (i_c == 0) | (tiles_ref[i_c] != tiles_ref[i_c - 1])

    @pl.when(is_first)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Indices may be stored int16 (compact-index precisions); the HBM/VMEM
    # traffic is paid at that width and the gather wants int32.
    cols = cols_ref[0].astype(jnp.int32)             # [chunk] slab-local
    slots = slots_ref[0].astype(jnp.int32)           # [chunk]
    vals = vals_ref[0]                               # [chunk]
    gathered = b_ref[...][cols]                      # [chunk, bd] row gather
    scaled = gathered * vals[:, None]
    # Segment sum as a matmul: onehot[r, j] = (slots[j] == r).
    rows = jax.lax.broadcasted_iota(jnp.int32, (row_tile, cols.shape[0]), 0)
    onehot = (rows == slots[None, :]).astype(scaled.dtype)
    o_ref[...] += jnp.dot(onehot, scaled,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("n", "row_tile", "b_tile", "block_d",
                                    "interpret"))
def csr_spmm_pallas(tile_ids: jnp.ndarray, b_tile_ids: jnp.ndarray,
                    cols: jnp.ndarray, row_slots: jnp.ndarray,
                    vals: jnp.ndarray, b: jnp.ndarray, *, n: int,
                    row_tile: int = 8, b_tile: Optional[int] = None,
                    block_d: int = 512, interpret: bool = True
                    ) -> jnp.ndarray:
    """C = A @ B with A given as row-tiled CSR chunks (csr_to_row_tiles).

    Args:
      tile_ids:   [C] int32 row-tile id per chunk (non-decreasing).
      b_tile_ids: [C] int32 B row-slab id per chunk (all zeros when the
                  layout was packed with ``b_tile=None``).
      cols:       [C, chunk] column ids (int32 or int16), slab-local,
                  zero-padded.
      row_slots:  [C, chunk] row index within the tile (int32 or int16),
                  zero-padded.
      vals:       [C, chunk] values, zero-padded.
      b:          [n, d] dense operand.
      n:          matrix dimension (static).
      row_tile:   rows per C tile (static).
      b_tile:     B rows per VMEM-resident slab (static); must match the
                  ``b_tile`` the layout was packed with.  None holds B
                  whole (single slab).
      block_d:    d-tile width (static).
      interpret:  run in interpret mode (CPU correctness path).
    """
    d = b.shape[1]
    bd = min(block_d, d)
    if d % bd != 0:
        raise ValueError(f"d={d} must be divisible by the d-tile {bd}")
    bt = b.shape[0] if b_tile is None else b_tile
    if b.shape[0] % bt != 0:
        pad = bt - b.shape[0] % bt
        b = jnp.concatenate([b, jnp.zeros((pad, d), b.dtype)])
    num_chunks, chunk = cols.shape
    num_tiles = (n + row_tile - 1) // row_tile
    grid = (d // bd, num_chunks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i_d, i_c, tiles, slabs: (i_c, 0)),
            pl.BlockSpec((1, chunk), lambda i_d, i_c, tiles, slabs: (i_c, 0)),
            pl.BlockSpec((1, chunk), lambda i_d, i_c, tiles, slabs: (i_c, 0)),
            pl.BlockSpec((bt, bd),
                         lambda i_d, i_c, tiles, slabs: (slabs[i_c], i_d)),
        ],
        out_specs=pl.BlockSpec(
            (row_tile, bd), lambda i_d, i_c, tiles, slabs: (tiles[i_c], i_d)),
    )
    out = pl.pallas_call(
        functools.partial(_csr_kernel, row_tile=row_tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_tiles * row_tile, d),
                                       jnp.float32),
        interpret=interpret,
    )(tile_ids, b_tile_ids, cols, row_slots, vals, b)
    return out[:n].astype(b.dtype)
