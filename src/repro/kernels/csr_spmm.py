"""Pallas TPU kernel: CSR row-gather / segment-sum SpMM.

TPU realization of the paper's CSR baseline (the random-regime
implementation): every nonzero gathers its row of B and the products are
segment-summed by destination row.  The kernel tiles that traversal so the
segment sum becomes an MXU matmul:

  * rows are grouped into tiles of ``row_tile`` rows; each tile's nonzeros
    are padded to whole chunks of ``chunk`` entries (sliced-ELL style
    packing of the CSR arrays, built host-side by ``csr_to_row_tiles``);
  * one grid step processes one chunk: it gathers ``chunk`` rows of B from
    the VMEM-resident operand, scales by the nonzero values, and reduces
    into the tile's C block with a one-hot [row_tile, chunk] matmul — the
    segment-sum expressed as MXU work instead of scatter traffic;
  * chunk -> row-tile ownership arrives via scalar prefetch (like the BCSR
    kernel's block coordinates), so the C tile stays resident in VMEM for
    all chunks of a tile and is written exactly once.

B is held whole in VMEM (BlockSpec over the full [n, bd] slab per d-tile):
the gather targets are data-dependent, so there is no index map that could
stream it.  That bounds this kernel to n * bd * 4 <= VMEM — fine for the
correctness scales exercised here; larger n would shard B's rows and
partial-sum C, which the dispatcher notes as a skip reason instead.

Padding slots carry value 0 (and column/row-slot 0), so they contribute
nothing; every row tile owns at least one chunk, so every C block is
visited and zeroed even for empty rows.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def csr_to_row_tiles(indptr: np.ndarray, indices: np.ndarray,
                     data: np.ndarray, *, n: int, row_tile: int = 8,
                     chunk: int = 128) -> Tuple[np.ndarray, np.ndarray,
                                                np.ndarray, np.ndarray]:
    """Pack CSR arrays into fixed-size chunks grouped by row tile.

    Returns ``(tile_ids[C], cols[C, chunk], row_slots[C, chunk],
    vals[C, chunk])`` where chunk ``c`` belongs to row tile ``tile_ids[c]``
    and ``row_slots`` are row indices *within* the tile.  Chunks of a tile
    are contiguous; empty tiles still get one all-zero chunk.
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    data = np.asarray(data)
    num_tiles = (n + row_tile - 1) // row_tile
    tile_ids, cols_c, slots_c, vals_c = [], [], [], []
    for tile in range(num_tiles):
        r0 = tile * row_tile
        r1 = min(r0 + row_tile, n)
        lo, hi = int(indptr[r0]), int(indptr[r1])
        cnt = hi - lo
        n_chunks = max(1, -(-cnt // chunk))
        cols = np.zeros(n_chunks * chunk, dtype=np.int32)
        slots = np.zeros(n_chunks * chunk, dtype=np.int32)
        vals = np.zeros(n_chunks * chunk, dtype=data.dtype)
        cols[:cnt] = indices[lo:hi]
        vals[:cnt] = data[lo:hi]
        row_of_nz = np.repeat(np.arange(r0, r1),
                              np.diff(indptr[r0:r1 + 1]).astype(np.int64))
        slots[:cnt] = (row_of_nz - r0).astype(np.int32)
        tile_ids.extend([tile] * n_chunks)
        cols_c.append(cols.reshape(n_chunks, chunk))
        slots_c.append(slots.reshape(n_chunks, chunk))
        vals_c.append(vals.reshape(n_chunks, chunk))
    return (np.asarray(tile_ids, dtype=np.int32),
            np.concatenate(cols_c), np.concatenate(slots_c),
            np.concatenate(vals_c))


def _csr_kernel(tiles_ref, cols_ref, slots_ref, vals_ref, b_ref, o_ref, *,
                row_tile: int):
    """One grid step: gather-scale one chunk, one-hot-matmul into its C tile."""
    i_c = pl.program_id(1)
    # First chunk of this row tile in this d-pass: zero the resident C block.
    is_first = (i_c == 0) | (tiles_ref[i_c] != tiles_ref[i_c - 1])

    @pl.when(is_first)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    cols = cols_ref[0]                               # [chunk]
    slots = slots_ref[0]                             # [chunk]
    vals = vals_ref[0]                               # [chunk]
    gathered = b_ref[...][cols]                      # [chunk, bd] row gather
    scaled = gathered * vals[:, None]
    # Segment sum as a matmul: onehot[r, j] = (slots[j] == r).
    rows = jax.lax.broadcasted_iota(jnp.int32, (row_tile, cols.shape[0]), 0)
    onehot = (rows == slots[None, :]).astype(scaled.dtype)
    o_ref[...] += jnp.dot(onehot, scaled,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("n", "row_tile", "block_d", "interpret"))
def csr_spmm_pallas(tile_ids: jnp.ndarray, cols: jnp.ndarray,
                    row_slots: jnp.ndarray, vals: jnp.ndarray,
                    b: jnp.ndarray, *, n: int, row_tile: int = 8,
                    block_d: int = 512, interpret: bool = True) -> jnp.ndarray:
    """C = A @ B with A given as row-tiled CSR chunks (csr_to_row_tiles).

    Args:
      tile_ids:  [C] int32 row-tile id per chunk (non-decreasing).
      cols:      [C, chunk] int32 column ids, zero-padded.
      row_slots: [C, chunk] int32 row index within the tile, zero-padded.
      vals:      [C, chunk] values, zero-padded.
      b:         [n, d] dense operand.
      n:         matrix dimension (static).
      row_tile:  rows per C tile (static).
      block_d:   d-tile width (static).
      interpret: run in interpret mode (CPU correctness path).
    """
    d = b.shape[1]
    bd = min(block_d, d)
    if d % bd != 0:
        raise ValueError(f"d={d} must be divisible by the d-tile {bd}")
    num_chunks, chunk = cols.shape
    num_tiles = (n + row_tile - 1) // row_tile
    grid = (d // bd, num_chunks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i_d, i_c, tiles: (i_c, 0)),
            pl.BlockSpec((1, chunk), lambda i_d, i_c, tiles: (i_c, 0)),
            pl.BlockSpec((1, chunk), lambda i_d, i_c, tiles: (i_c, 0)),
            pl.BlockSpec((n, bd), lambda i_d, i_c, tiles: (0, i_d)),
        ],
        out_specs=pl.BlockSpec(
            (row_tile, bd), lambda i_d, i_c, tiles: (tiles[i_c], i_d)),
    )
    out = pl.pallas_call(
        functools.partial(_csr_kernel, row_tile=row_tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_tiles * row_tile, d),
                                       jnp.float32),
        interpret=interpret,
    )(tile_ids, cols, row_slots, vals, b)
    return out[:n].astype(b.dtype)
