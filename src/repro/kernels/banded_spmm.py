"""Pallas TPU kernel: banded (diagonal-regime) SpMM.

Realizes the paper's diagonal-sparsity model (Eq. 3) on TPU: for a band of
half-width w (in t x t blocks), each block row multiplies at most 2w+1
diagonal-adjacent blocks.  Because consecutive block rows touch overlapping
B tiles, B is streamed HBM->VMEM essentially once — the TPU counterpart of
"B is loaded once into cache".

A is stored densely as ``band[nb, W, t, t]`` with W = 2w+1; edge blocks are
zero-padded so index maps never need masking (a zero block contributes
nothing while the clamped B tile it multiplies is already resident).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _banded_kernel(a_ref, b_ref, o_ref, *, w: int):
    del w
    o = pl.program_id(2)

    @pl.when(o == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[0, 0], b_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("t", "w", "block_d", "interpret"))
def banded_spmm_pallas(band: jnp.ndarray, b: jnp.ndarray, *, t: int, w: int,
                       block_d: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """C = A @ B for banded A.

    Args:
      band: [nb, 2w+1, t, t] block diagonals; band[i, o] is the block at
            block position (i, i + o - w), zero where out of range.
      b:    [n, d] dense operand; n = nb * t.
      t, w: block edge and half-width in blocks (static).
    """
    nb, W, _, _ = band.shape
    assert W == 2 * w + 1, (W, w)
    n, d = b.shape
    assert n == nb * t, (n, nb, t)
    bd = min(block_d, d)
    if d % bd != 0:
        raise ValueError(f"d={d} not divisible by d-tile {bd}")
    grid = (d // bd, nb, W)

    def a_map(i_d, i, o):
        return (i, o, 0, 0)

    def b_map(i_d, i, o):
        col = jnp.clip(i + o - w, 0, nb - 1)
        return (col, i_d)

    def o_map(i_d, i, o):
        return (i, i_d)

    out = pl.pallas_call(
        functools.partial(_banded_kernel, w=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, t, t), a_map),
            pl.BlockSpec((t, bd), b_map),
        ],
        out_specs=pl.BlockSpec((t, bd), o_map),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(band, b)
    return out.astype(b.dtype)
