"""Hardware descriptions used by the roofline models.

The paper's testbed is one socket of an AMD EPYC 7763 (Perlmutter CPU node);
our deployment target is a TPU v5e pod slice.  Both are expressed with the
same dataclass so every roofline routine is hardware-agnostic.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Architectural ceilings for a single device (chip / socket)."""

    name: str
    peak_flops: float          # FLOP/s (per device) at the relevant precision
    hbm_bandwidth: float       # bytes/s main-memory bandwidth (per device)
    link_bandwidth: float      # bytes/s per inter-device link (0 => none)
    vmem_bytes: int = 0        # software-managed fast memory (VMEM / LLC)
    hbm_bytes: int = 0         # main memory capacity per device
    mxu_tile: tuple = (128, 128)  # native matmul tile (rows, cols)
    #: Aggregate interconnect bandwidth one device can drive during a
    #: collective (bytes/s).  0 means "unknown": model collectives at the
    #: per-link ``link_bandwidth``, or — when that is 0 too (single-host
    #: virtual devices) — at ``hbm_bandwidth``, since virtual-device
    #: collectives are memcpys through the same DRAM.
    ici_bytes_per_s: float = 0.0
    #: Fixed launch/synchronization latency per collective hop (seconds);
    #: collectives pay ``ceil(log2(devices))`` hops in the cost model.
    collective_latency_s: float = 10e-6

    @property
    def ridge_point(self) -> float:
        """Arithmetic intensity where memory-bound meets compute-bound."""
        return self.peak_flops / self.hbm_bandwidth

    def attainable(self, ai: float) -> float:
        """Classic roofline: P = min(beta * AI, pi)."""
        return min(self.hbm_bandwidth * ai, self.peak_flops)

    @property
    def collective_bandwidth(self) -> float:
        """Effective bytes/s a device moves during collectives (see
        ``ici_bytes_per_s`` for the fallback chain)."""
        return (self.ici_bytes_per_s or self.link_bandwidth
                or self.hbm_bandwidth)

    def fingerprint(self) -> str:
        """Stable id of this spec's *compute* identity (12 hex chars).

        Keys persisted kernel calibrations (``repro.core.calibrate``): a
        calibration fitted on one device must not be applied to another.
        Bandwidth fields are deliberately excluded — ``hbm_bandwidth`` is
        routinely replaced by the run-time STREAM measurement
        (``benchmarks/spmm_suite.make_dispatcher``), and the fitted
        ``(peak_fraction, d_half)`` ceilings describe the compute side
        of the roofline, which that substitution does not change.  The
        interconnect fields (``ici_bytes_per_s``,
        ``collective_latency_s``) are excluded for the same reason: they
        only enter the sharded communication model, never the per-device
        compute ceiling a calibration fits.
        """
        payload = json.dumps({
            "name": self.name, "peak_flops": self.peak_flops,
            "vmem_bytes": self.vmem_bytes,
            "mxu_tile": list(self.mxu_tile),
        }, sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:12]


# --- The paper's evaluation platform (Table IV + measured STREAM beta). ---
PERLMUTTER_MILAN = HardwareSpec(
    name="amd-epyc-7763-1socket",
    peak_flops=64 * 2.45e9 * 16,      # 64 cores x 2.45 GHz x (AVX2 FMA: 16 dp flop/cyc)
    hbm_bandwidth=122.6e9,            # STREAM-measured in the paper
    link_bandwidth=0.0,
    vmem_bytes=256 * 2**20,           # 256 MiB L3 per socket
    hbm_bytes=512 * 2**30,
    mxu_tile=(1, 4),                  # AVX2 dp vector as the "tile"
)

# --- Deployment target: TPU v5e (per chip), constants from the task spec. ---
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,                # bf16
    hbm_bandwidth=819e9,
    link_bandwidth=50e9,              # per ICI link
    vmem_bytes=128 * 2**20,
    hbm_bytes=16 * 2**30,
    mxu_tile=(128, 128),
    ici_bytes_per_s=4 * 50e9,         # 4 ICI links per chip (2D torus)
    collective_latency_s=1e-6,
)

# Host CPU of this container (used only for wall-clock benchmark *context*;
# beta is measured at runtime by benchmarks/stream.py, mirroring the paper).
HOST_CPU = HardwareSpec(
    name="container-host-cpu",
    peak_flops=50e9,
    hbm_bandwidth=10e9,               # placeholder; STREAM overrides at runtime
    link_bandwidth=0.0,
    vmem_bytes=32 * 2**20,
    hbm_bytes=35 * 2**30,
    mxu_tile=(1, 4),
    # Virtual host devices share one DRAM: collectives are memcpys, so
    # collective_bandwidth falls back to hbm_bandwidth (ici stays 0).
    collective_latency_s=20e-6,
)


def by_name(name: str) -> HardwareSpec:
    table = {h.name: h for h in (PERLMUTTER_MILAN, TPU_V5E, HOST_CPU)}
    table.update({"v5e": TPU_V5E, "milan": PERLMUTTER_MILAN, "host": HOST_CPU})
    return table[name]
