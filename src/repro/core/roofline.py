"""Roofline model plumbing (paper Section II-C) plus the three-term
distributed extension used for the TPU dry-run analysis.

The classic single-device roofline is ``P = min(beta * AI, pi)``.  For a
pod-scale deployment we report the three time terms per training/serving step:

  compute    = FLOPs / (chips * peak_flops)
  memory     = bytes / (chips * hbm_bandwidth)
  collective = collective_bytes / (chips * link_bandwidth)

The dominant term is the bottleneck; the step can never run faster than
max(compute, memory, collective) under perfect overlap, nor slower than their
sum under zero overlap.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.hardware import HardwareSpec
from repro.core.sparsity_models import TrafficBreakdown


@dataclasses.dataclass(frozen=True)
class ComputeCeiling:
    """A format implementation's compute ceiling on one host.

    The dispatcher caps the bandwidth roofline ``beta * AI`` with

        peak * peak_fraction * useful_fraction * d / (d + d_half)

    in useful FLOP/s: ``peak_fraction`` is the fraction of hardware peak
    the implementation sustains at large d on its *issued* FLOPs,
    ``d_half`` the dense width at which per-nonzero index/bookkeeping
    overhead halves throughput (it amortizes over the d dense columns).
    ``source`` records provenance: ``"default"`` (the baked-in container
    constants), ``"calibrated"`` (fitted by ``repro.core.calibrate`` on
    this host), or ``"override"`` (``Dispatcher(efficiency=...)``).
    """

    peak_fraction: float
    d_half: float
    source: str = "default"

    def attainable(self, peak_flops: float, useful_fraction: float,
                   d: int) -> float:
        """The ceiling in useful FLOP/s for dense width ``d``."""
        return (peak_flops * self.peak_fraction * useful_fraction
                * d / (d + self.d_half))


@dataclasses.dataclass(frozen=True)
class RooflinePoint:
    """One kernel/workload placed on a device roofline."""

    name: str
    ai: float                       # FLOPs / byte
    flops: float                    # total useful FLOPs
    hardware: HardwareSpec
    attained_flops_per_s: Optional[float] = None   # measured, if available

    @property
    def bound(self) -> str:
        return "compute" if self.ai >= self.hardware.ridge_point else "memory"

    @property
    def attainable_flops_per_s(self) -> float:
        return self.hardware.attainable(self.ai)

    @property
    def roofline_fraction(self) -> Optional[float]:
        """Measured / attainable; None when nothing was measured."""
        if self.attained_flops_per_s is None:
            return None
        return self.attained_flops_per_s / self.attainable_flops_per_s


def place(name: str, traffic: TrafficBreakdown, hw: HardwareSpec,
          attained: Optional[float] = None) -> RooflinePoint:
    """Place a sparsity-model traffic estimate on a hardware roofline."""
    return RooflinePoint(name=name, ai=traffic.ai, flops=traffic.flops,
                         hardware=hw, attained_flops_per_s=attained)


@dataclasses.dataclass(frozen=True)
class DistributedRoofline:
    """Three-term roofline for one (arch x shape x mesh) dry-run cell."""

    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    hardware: HardwareSpec
    model_flops: float = 0.0        # 6*N*D (+attention) useful FLOPs

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * self.hardware.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * self.hardware.hbm_bandwidth)

    @property
    def collective_s(self) -> float:
        if self.hardware.link_bandwidth <= 0:
            return 0.0
        return self.collective_bytes / (self.chips * self.hardware.link_bandwidth)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        """Perfect-overlap bound: the slowest of the three pipes."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_compute_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundancy waste."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    @property
    def mfu_upper_bound(self) -> float:
        """Model-FLOPs utilization ceiling implied by the dominant term."""
        denom = self.step_time_lower_bound_s * self.chips * self.hardware.peak_flops
        if denom <= 0:
            return 0.0
        return self.model_flops / denom

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_compute_ratio": self.useful_compute_ratio,
            "mfu_upper_bound": self.mfu_upper_bound,
            "step_time_lower_bound_s": self.step_time_lower_bound_s,
        }
