"""Roofline model plumbing (paper Section II-C) plus the three-term
distributed extension used for the TPU dry-run analysis.

The classic single-device roofline is ``P = min(beta * AI, pi)``.  For a
pod-scale deployment we report the three time terms per training/serving step:

  compute    = FLOPs / (chips * peak_flops)
  memory     = bytes / (chips * hbm_bandwidth)
  collective = collective_bytes / (chips * link_bandwidth)

The dominant term is the bottleneck; the step can never run faster than
max(compute, memory, collective) under perfect overlap, nor slower than their
sum under zero overlap.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.hardware import HardwareSpec
from repro.core.sparsity_models import TrafficBreakdown


@dataclasses.dataclass(frozen=True)
class ComputeCeiling:
    """A format implementation's compute ceiling on one host.

    The dispatcher caps the bandwidth roofline ``beta * AI`` with

        peak * peak_fraction * useful_fraction * d / (d + d_half)

    in useful FLOP/s: ``peak_fraction`` is the fraction of hardware peak
    the implementation sustains at large d on its *issued* FLOPs,
    ``d_half`` the dense width at which per-nonzero index/bookkeeping
    overhead halves throughput (it amortizes over the d dense columns).
    ``source`` records provenance: ``"default"`` (the baked-in container
    constants), ``"calibrated"`` (fitted by ``repro.core.calibrate`` on
    this host), or ``"override"`` (``Dispatcher(efficiency=...)``).
    """

    peak_fraction: float
    d_half: float
    source: str = "default"

    def attainable(self, peak_flops: float, useful_fraction: float,
                   d: int) -> float:
        """The ceiling in useful FLOP/s for dense width ``d``."""
        return (peak_flops * self.peak_fraction * useful_fraction
                * d / (d + self.d_half))


@dataclasses.dataclass(frozen=True)
class RooflinePoint:
    """One kernel/workload placed on a device roofline."""

    name: str
    ai: float                       # FLOPs / byte
    flops: float                    # total useful FLOPs
    hardware: HardwareSpec
    attained_flops_per_s: Optional[float] = None   # measured, if available

    @property
    def bound(self) -> str:
        return "compute" if self.ai >= self.hardware.ridge_point else "memory"

    @property
    def attainable_flops_per_s(self) -> float:
        return self.hardware.attainable(self.ai)

    @property
    def roofline_fraction(self) -> Optional[float]:
        """Measured / attainable; None when nothing was measured."""
        if self.attained_flops_per_s is None:
            return None
        return self.attained_flops_per_s / self.attainable_flops_per_s


def place(name: str, traffic: TrafficBreakdown, hw: HardwareSpec,
          attained: Optional[float] = None) -> RooflinePoint:
    """Place a sparsity-model traffic estimate on a hardware roofline."""
    return RooflinePoint(name=name, ai=traffic.ai, flops=traffic.flops,
                         hardware=hw, attained_flops_per_s=attained)


def collective_time(bytes_on_wire: float, hw: HardwareSpec,
                    devices: int, *, collectives: int = 1) -> float:
    """Seconds one device spends moving ``bytes_on_wire`` collectively.

    The cost model is the standard ring/tree hybrid: a bandwidth term
    (bytes over ``hw.collective_bandwidth``) plus a latency term of
    ``collectives * collective_latency_s * ceil(log2(devices))`` — each
    collective synchronizes the mesh over ~log2(D) hops regardless of
    payload.  With one device there is no wire and the cost is 0.

    Args:
        bytes_on_wire: per-device bytes the collective moves (for ring
            all-gather / reduce-scatter of an ``S``-byte global buffer
            this is ``(D-1)/D * S``).
        hw: hardware spec supplying ``collective_bandwidth`` and
            ``collective_latency_s``.
        devices: mesh size D.
        collectives: number of distinct collective launches to charge
            latency for.

    Returns:
        Modeled seconds.
    """
    if devices <= 1:
        return 0.0
    hops = math.ceil(math.log2(devices))
    bw = hw.collective_bandwidth
    transfer = bytes_on_wire / bw if bw > 0 else 0.0
    return transfer + collectives * hw.collective_latency_s * hops


@dataclasses.dataclass(frozen=True)
class ShardRoofline:
    """Per-shard roofline: the sparsity-aware AI of the *critical* shard
    plus the collective term of the chosen B-distribution strategy.

    This is the sharded tier's analogue of :class:`RooflinePoint`: the
    compute/memory side is evaluated on the most loaded shard (the SPMD
    program runs at the speed of its slowest participant), and the
    communication side adds the strategy's collective bytes at
    ``collective_bandwidth``.  ``predicted_flops_per_s`` is the
    whole-matrix useful FLOP rate under zero compute/communication
    overlap — conservative, matching how shard_map sequences the
    collective after the local kernel.
    """

    strategy: str                  # "replicate" | "all_gather" | "reduce_scatter"
    devices: int
    shard_ai: float                # AI of the most loaded shard
    critical_flops: float          # useful FLOPs on the most loaded shard
    total_flops: float             # useful FLOPs of the whole SpMM
    compute_s: float               # critical shard local kernel time
    collective_s: float            # strategy's collective cost
    collective_bytes: float        # per-device bytes on the wire

    @property
    def total_s(self) -> float:
        """Zero-overlap step time: local compute + collectives."""
        return self.compute_s + self.collective_s

    @property
    def predicted_flops_per_s(self) -> float:
        """Whole-matrix useful FLOP/s implied by ``total_s``."""
        if self.total_s <= 0:
            return 0.0
        return self.total_flops / self.total_s

    @property
    def dominant(self) -> str:
        """Which term binds: ``"compute"`` or ``"collective"``."""
        return ("collective" if self.collective_s > self.compute_s
                else "compute")


@dataclasses.dataclass(frozen=True)
class DistributedRoofline:
    """Three-term roofline for one (arch x shape x mesh) dry-run cell."""

    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    hardware: HardwareSpec
    model_flops: float = 0.0        # 6*N*D (+attention) useful FLOPs

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * self.hardware.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * self.hardware.hbm_bandwidth)

    @property
    def collective_s(self) -> float:
        if self.hardware.link_bandwidth <= 0:
            return 0.0
        return self.collective_bytes / (self.chips * self.hardware.link_bandwidth)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        """Perfect-overlap bound: the slowest of the three pipes."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_compute_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundancy waste."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    @property
    def mfu_upper_bound(self) -> float:
        """Model-FLOPs utilization ceiling implied by the dominant term."""
        denom = self.step_time_lower_bound_s * self.chips * self.hardware.peak_flops
        if denom <= 0:
            return 0.0
        return self.model_flops / denom

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_compute_ratio": self.useful_compute_ratio,
            "mfu_upper_bound": self.mfu_upper_bound,
            "step_time_lower_bound_s": self.step_time_lower_bound_s,
        }
