"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-based program (layer stacks, flash-attention block loops, microbatch
accumulation) is undercounted by the product of its trip counts.  This
module re-derives the three roofline inputs directly from the post-SPMD HLO
text with loop multipliers applied:

  * dot FLOPs:       2 * prod(result dims) * prod(contracting dims)
  * bytes accessed:  operand + result bytes of every *top-level* instruction
                     (fusion/reduce internals excluded, mirroring XLA's own
                     definition), times the enclosing loop multiplier
  * collective bytes: operand bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute,
                     times the loop multiplier

Trip counts are read from each while's condition computation (jax lowers
``lax.scan``/``fori_loop`` to ``iv < constant(N)``).  Conditional branches
contribute the max over branches.  The model is validated against closed
-form FLOP counts in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_instr(line: str):
    """Split '%name = <result shape> opcode(operands...), attrs' robustly.

    Tuple result shapes may contain '/*index=N*/' comments (with '=') and
    nested parens, so this walks the text instead of using a single regex.
    Returns (name, result_text, opcode, operand_text) or None.
    """
    m = _INSTR_HEAD_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        result_text = rest[:end + 1]
        after = rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        result_text = rest[:sp]
        after = rest[sp:]
    m2 = _OPCODE_RE.match(after)
    if not m2:
        return None
    opcode = m2.group(1)
    body = after[m2.end():]
    depth, buf = 1, []
    for ch in body:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return name, result_text, opcode, "".join(buf)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy", "copy-start", "copy-done", "after-all",
    "partition-id", "replica-id", "iota", "custom-call",
    # Control-flow wrappers: their bodies are traversed separately, and
    # their operand tuples alias in place — counting them would charge the
    # whole loop carry per step.
    "while", "conditional", "call", "optimization-barrier",
}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Sum of (elements, bytes) over every shape token in ``text``."""
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        count = 1
        if dims:
            for d in dims.split(","):
                if d:
                    count *= int(d)
        elems += count
        nbytes += count * _DTYPE_BYTES[dtype]
    return elems, nbytes


def _first_shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = m.group(2)
    if dims == "":
        return []
    return [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_text: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    by_name: Dict[str, Instruction]


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, Computation] = {}
        self._parse(hlo_text)
        self._multipliers = self._compute_multipliers()

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        current: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            head = _COMP_HEAD_RE.match(line)
            if head and line.endswith("{"):
                current = Computation(head.group(1), [], {})
                self.computations[current.name] = current
                if "ENTRY" in line:
                    self.entry = current.name
                continue
            if current is None:
                continue
            if line.strip() == "}":
                current = None
                continue
            parts = _split_instr(line)
            if parts is None:
                continue
            name, result_text, opcode, operand_text = parts
            operands = _OPERAND_RE.findall(operand_text)
            instr = Instruction(name, opcode, result_text, line, operands)
            current.instructions.append(instr)
            current.by_name[name] = instr

    # ------------------------------------------------------------------
    def _operand_shape_text(self, comp: Computation, op_name: str) -> str:
        instr = comp.by_name.get(op_name)
        if instr is not None:
            return instr.result_text
        for c in self.computations.values():
            if op_name in c.by_name:
                return c.by_name[op_name].result_text
        return ""

    def _trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for instr in comp.instructions:
            for m in _CONST_RE.finditer(instr.line):
                best = max(best, int(m.group(1)))
        return best

    def _compute_multipliers(self) -> Dict[str, float]:
        mult: Dict[str, float] = defaultdict(float)
        mult[self.entry] = 1.0
        # Propagate through while bodies and conditional branches only;
        # fusion internals and reduce/sort appliers do not touch memory.
        frontier = [self.entry]
        seen_edges = set()
        while frontier:
            cname = frontier.pop()
            cmult = mult[cname]
            comp = self.computations[cname]
            for instr in comp.instructions:
                if instr.opcode == "while":
                    body = re.search(r"body=%?([\w.\-]+)", instr.line)
                    cond = re.search(r"condition=%?([\w.\-]+)", instr.line)
                    if body:
                        trips = self._trip_count(cond.group(1)) if cond \
                            else 1
                        key = (cname, instr.name, body.group(1))
                        if key in seen_edges:
                            continue
                        seen_edges.add(key)
                        mult[body.group(1)] += cmult * trips
                        frontier.append(body.group(1))
                elif instr.opcode == "call":
                    # XLA CPU wraps parallelized fusions in %call /
                    # to_apply; the callee runs exactly once per call.
                    target = re.search(r"to_apply=%?([\w.\-]+)", instr.line)
                    if target:
                        key = (cname, instr.name, target.group(1))
                        if key in seen_edges:
                            continue
                        seen_edges.add(key)
                        mult[target.group(1)] += cmult
                        frontier.append(target.group(1))
                elif instr.opcode == "conditional":
                    branches = re.findall(
                        r"(?:true_computation|false_computation|"
                        r"branch_computations=\{)([^,}]+)", instr.line)
                    names = re.findall(r"%([\w.\-]+)", ",".join(branches))
                    for b in names:
                        key = (cname, instr.name, b)
                        if key in seen_edges:
                            continue
                        seen_edges.add(key)
                        mult[b] += cmult
                        frontier.append(b)
        return dict(mult)

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: Computation, instr: Instruction) -> float:
        out_dims = _first_shape_dims(instr.result_text) or []
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
        contract = 1
        if m and instr.operands:
            lhs_text = self._operand_shape_text(comp, instr.operands[0])
            lhs_dims = _first_shape_dims(lhs_text) or []
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
        return 2.0 * out_elems * contract

    def _instr_bytes(self, comp: Computation, instr: Instruction) -> float:
        """Bytes an instruction moves, modelling in-place slice updates.

        dynamic-update-slice aliases its operand: true traffic is the
        update region (read+write), not the whole buffer; likewise
        dynamic-slice/gather read only the slice they produce.
        """
        op = instr.opcode
        _, rb = _shape_elems_bytes(instr.result_text)
        if op == "fusion":
            # XLA aliases in-place update fusions: only the update region
            # moves.  Slice-producing fusions read just the slice.
            if "dynamic-update-slice" in instr.name:
                upd = 0
                for o in instr.operands[1:]:
                    _, b = _shape_elems_bytes(
                        self._operand_shape_text(comp, o))
                    upd += b
                return 2.0 * min(upd, rb) if upd else 2.0 * rb
            if "slice" in instr.name or "gather" in instr.name:
                return 2.0 * rb
        if op == "dynamic-slice":
            return 2.0 * rb
        if op == "dynamic-update-slice":
            upd = 0
            if len(instr.operands) >= 2:
                _, upd = _shape_elems_bytes(
                    self._operand_shape_text(comp, instr.operands[1]))
            return 2.0 * upd
        if op == "gather":
            return 2.0 * rb
        if op == "scatter":
            upd = 0
            if len(instr.operands) >= 3:
                _, upd = _shape_elems_bytes(
                    self._operand_shape_text(comp, instr.operands[2]))
            return 2.0 * upd + rb
        ob = 0
        for o in instr.operands:
            _, b = _shape_elems_bytes(self._operand_shape_text(comp, o))
            ob += b
        return rb + ob

    def summarize(self) -> Dict[str, float]:
        flops = 0.0
        bytes_accessed = 0.0
        coll_bytes: Dict[str, float] = defaultdict(float)
        coll_counts: Dict[str, float] = defaultdict(float)
        for cname, comp in self.computations.items():
            mult = self._multipliers.get(cname)
            if not mult:
                continue
            for instr in comp.instructions:
                op = instr.opcode
                base = op[:-6] if op.endswith("-start") else op
                if op in ("dot", "dot_general") or op.startswith("dot"):
                    flops += mult * self._dot_flops(comp, instr)
                if op.endswith("-done"):
                    continue
                if base in _COLLECTIVES:
                    nbytes = 0
                    for o in instr.operands:
                        _, b = _shape_elems_bytes(
                            self._operand_shape_text(comp, o))
                        nbytes += b
                    coll_bytes[base] += mult * nbytes
                    coll_counts[base] += mult
                if op in _SKIP_BYTES_OPS or base in _COLLECTIVES:
                    continue
                bytes_accessed += mult * self._instr_bytes(comp, instr)
        coll_bytes["total"] = sum(coll_bytes.values())
        return {
            "flops": flops,
            "bytes_accessed": bytes_accessed,
            "collective_bytes": dict(coll_bytes),
            "collective_counts": dict(coll_counts),
        }


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    return HloCostModel(hlo_text).summarize()


def top_contributors(hlo_text: str, kind: str = "collective",
                     k: int = 12) -> List[Tuple[float, str]]:
    """Largest individual cost contributors, for perf diagnosis.

    kind: "collective" (bytes), "bytes", or "flops".
    Returns [(total_contribution, description), ...] descending.
    """
    model = HloCostModel(hlo_text)
    rows: List[Tuple[float, str]] = []
    for cname, comp in model.computations.items():
        mult = model._multipliers.get(cname)
        if not mult:
            continue
        for instr in comp.instructions:
            op = instr.opcode
            base = op[:-6] if op.endswith("-start") else op
            if kind == "collective":
                if base not in _COLLECTIVES or op.endswith("-done"):
                    continue
                nbytes = sum(
                    _shape_elems_bytes(
                        model._operand_shape_text(comp, o))[1]
                    for o in instr.operands)
                rows.append((mult * nbytes,
                             f"{base} x{mult:.0f} {instr.result_text[:60]}"
                             f" @{cname[:40]}"))
            elif kind == "flops" and op.startswith("dot"):
                rows.append((mult * model._dot_flops(comp, instr),
                             f"dot x{mult:.0f} {instr.line[:90]}"))
            elif kind == "bytes":
                if op in _SKIP_BYTES_OPS or base in _COLLECTIVES:
                    continue
                rows.append((mult * model._instr_bytes(comp, instr),
                             f"{op} x{mult:.0f} {instr.name[:40]} "
                             f"{instr.result_text[:50]}"))
    rows.sort(key=lambda r: -r[0])
    return rows[:k]
