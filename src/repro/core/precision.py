"""Precision specs: value/index storage dtypes as a dispatch axis.

SpMM is bandwidth-bound (the paper's central claim), and bytes are
dominated by per-nonzero value + index traffic — so halving element
sizes roughly doubles the bandwidth ceiling ``beta * AI``.  A
:class:`Precision` names the storage dtypes of a packed layout;
arithmetic always accumulates in fp32 (``preferred_element_type``, fp32
VMEM accumulators), so only memory traffic and operand rounding change.

This lives in ``repro.core`` so the kernel registry can consume it at
import time; the user-facing home is ``repro.sparse.formats`` (and the
``repro.sparse`` package root), which re-export everything here.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

#: Storage bytes per element for the value/index dtypes a layout may pack.
_VALUE_DTYPES = {"float32": 4, "bfloat16": 2}
_INDEX_DTYPES = {"int32": 4, "int16": 2}

#: Largest addressed extent a packed int16 index vector may cover.  The
#: packers reserve one sentinel slot equal to the extent itself (rowsplit's
#: dropped-row id, one-past-the-end padding), so the extent — not just
#: ``extent - 1`` — must be representable: extents up to ``2**15 - 1`` are
#: legal, ``2**15`` is not.
INT16_MAX_EXTENT = 2 ** 15 - 1


def int16_extent_ok(extent: int) -> bool:
    """True iff int16 indices may address ``extent`` positions.

    Legality is strict at the boundary: ``extent == 2**15`` is illegal
    because the sentinel index equal to the extent would overflow.
    """
    return 0 <= int(extent) <= INT16_MAX_EXTENT


@dataclasses.dataclass(frozen=True)
class Precision:
    """Value/index storage precision of a packed sparse layout.

    Dtypes are held as strings so the spec is hashable (cache keys),
    comparable, and JSON/CSV-serializable without dtype imports.
    """

    value_dtype: str = "float32"   # "float32" | "bfloat16"
    index_dtype: str = "int32"     # "int32" | "int16"

    def __post_init__(self):
        if self.value_dtype not in _VALUE_DTYPES:
            raise ValueError(
                f"value_dtype must be one of {sorted(_VALUE_DTYPES)}, "
                f"got {self.value_dtype!r}")
        if self.index_dtype not in _INDEX_DTYPES:
            raise ValueError(
                f"index_dtype must be one of {sorted(_INDEX_DTYPES)}, "
                f"got {self.index_dtype!r}")

    @property
    def sizeof_val(self) -> int:
        """Bytes per stored value element."""
        return _VALUE_DTYPES[self.value_dtype]

    @property
    def sizeof_idx(self) -> int:
        """Bytes per stored index element."""
        return _INDEX_DTYPES[self.index_dtype]

    @property
    def value_jnp(self):
        """The value dtype as a jnp dtype object (bf16 via ml_dtypes)."""
        return jnp.bfloat16 if self.value_dtype == "bfloat16" else jnp.float32

    @property
    def index_np(self):
        """The index dtype as a numpy dtype object."""
        return np.int16 if self.index_dtype == "int16" else np.int32

    @property
    def eps(self) -> float:
        """Machine epsilon of the value dtype (tolerance scaling)."""
        return float(jnp.finfo(self.value_jnp).eps)

    @property
    def reduced(self) -> bool:
        """True when values are stored below fp32."""
        return self.value_dtype != "float32"

    @property
    def token(self) -> str:
        """Short stable name (cache keys, CSV ``dtype`` column)."""
        v = "bf16" if self.value_dtype == "bfloat16" else "f32"
        i = "i16" if self.index_dtype == "int16" else "i32"
        return f"{v}{i}"

    def index_ok(self, extent: int) -> bool:
        """True iff this spec's index dtype can address ``extent``."""
        return self.index_dtype == "int32" or int16_extent_ok(extent)


#: The canonical points on the precision axis the dispatcher enumerates.
PRECISION_FP32 = Precision("float32", "int32")
PRECISION_BF16 = Precision("bfloat16", "int16")
PRECISION_BF16_I32 = Precision("bfloat16", "int32")
DEFAULT_PRECISION = PRECISION_FP32
PRECISIONS = (PRECISION_FP32, PRECISION_BF16_I32, PRECISION_BF16)

_PRECISION_ALIASES = {
    "f32": PRECISION_FP32, "fp32": PRECISION_FP32,
    "float32": PRECISION_FP32, "f32i32": PRECISION_FP32,
    "bf16": PRECISION_BF16, "bfloat16": PRECISION_BF16,
    "bf16i16": PRECISION_BF16, "bf16i32": PRECISION_BF16_I32,
}


def as_precision(spec) -> Precision:
    """Coerce a user-facing precision argument to a :class:`Precision`.

    Accepts a ``Precision``, ``None`` (the fp32 default), or a short
    string token (``"fp32"``, ``"bf16"``, ``"bf16i32"``, ``"bf16i16"``).
    """
    if spec is None:
        return DEFAULT_PRECISION
    if isinstance(spec, Precision):
        return spec
    if isinstance(spec, str):
        try:
            return _PRECISION_ALIASES[spec.lower()]
        except KeyError:
            raise ValueError(
                f"unknown precision {spec!r}; expected one of "
                f"{sorted(_PRECISION_ALIASES)}") from None
    raise TypeError(f"cannot interpret {spec!r} as a Precision")
