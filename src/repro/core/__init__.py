"""Core contribution: sparsity-aware roofline models for SpMM."""
from repro.core.hardware import HardwareSpec, PERLMUTTER_MILAN, TPU_V5E, by_name
from repro.core.roofline import DistributedRoofline, RooflinePoint, place
from repro.core.sparsity_models import (
    TrafficBreakdown,
    ai_blocked,
    ai_blocked_tpu,
    ai_diagonal,
    ai_random,
    ai_scale_free,
    arithmetic_intensity,
    expected_occupied_columns,
    flops_spmm,
    hub_edge_fraction,
    mxu_utilization,
)
from repro.core.patterns import (
    COOMatrix, banded, block_diagonal, blocked, erdos_renyi, fit_generator,
    scale_free, serving_suite,
)
from repro.core.classify import StructureReport, classify

__all__ = [
    "HardwareSpec", "PERLMUTTER_MILAN", "TPU_V5E", "by_name",
    "DistributedRoofline", "RooflinePoint", "place",
    "TrafficBreakdown", "ai_blocked", "ai_blocked_tpu", "ai_diagonal",
    "ai_random", "ai_scale_free", "arithmetic_intensity",
    "expected_occupied_columns", "flops_spmm", "hub_edge_fraction",
    "mxu_utilization",
    "COOMatrix", "banded", "block_diagonal", "blocked", "erdos_renyi",
    "fit_generator", "scale_free", "serving_suite",
    "StructureReport", "classify",
]
