"""On-host calibration of the per-format compute ceilings.

The dispatcher caps every bandwidth roofline with a format compute
ceiling ``peak * peak_fraction * useful * d / (d + d_half)``.  The
``(peak_fraction, d_half)`` pairs shipped in
``repro.sparse.dispatch.DEFAULT_EFFICIENCY`` were measured on one
container and baked in — exactly the practice SpChar (Sgherzi et al.,
2023) warns against: ceiling parameters are properties of a (host,
implementation) pair and must be learned where the code runs.

This module replaces the constants with a measurement:

    from repro.core.calibrate import CalibrationStore, calibrate
    cal = calibrate(hw)                  # short microbenchmark sweep
    CalibrationStore().save(cal)         # persists per-host JSON
    # Dispatcher picks it up automatically; CandidateEval.ceiling_source
    # flips from "default" to "calibrated".

``calibrate`` runs, per registered kernel spec (``(format, backend)`` in
``repro.kernels.registry``), a small structure-matched SpMM at several
dense widths, and fits the ceiling shape ``g(d) = G * d / (d + d_half)``
to the measured useful GFLOP/s via the linearization

    1/g = (1/G) + (d_half/G) * (1/d)        (least squares on 1/d)

so ``peak_fraction = G / (peak * useful_fraction)``.  Results are
persisted as JSON under ``~/.cache/repro/calibrations/`` (override with
``$REPRO_CALIBRATION_DIR``), one file per ``HardwareSpec.name``, stamped
with ``HardwareSpec.fingerprint()``; a stale file whose fingerprint no
longer matches the active spec is ignored, falling back to the defaults.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import HardwareSpec

#: Dense widths for the fit: spread in 1/d so both the asymptote and the
#: half-saturation width are constrained.
DEFAULT_D_VALUES: Tuple[int, ...] = (4, 16, 64, 256)

#: Clamps keeping a noisy fit inside physically meaningful territory.
PEAK_FRACTION_RANGE: Tuple[float, float] = (1e-5, 1.0)
D_HALF_RANGE: Tuple[float, float] = (0.0, 4096.0)


def fit_ceiling(d_values: Sequence[int],
                gflops: Sequence[float]) -> Tuple[float, float]:
    """Fit ``g(d) = G * d / (d + d_half)`` to measured throughputs.

    Args:
        d_values: dense widths of the sweep (>= 2 distinct values).
        gflops: measured useful GFLOP/s at each width.

    Returns:
        ``(G, d_half)`` — the saturated throughput (same unit as the
        input) and the half-saturation width.  Degenerate sweeps (flat,
        noisy-decreasing, or non-positive) fall back to
        ``(max(gflops), 0.0)``.
    """
    d = np.asarray(list(d_values), dtype=np.float64)
    g = np.asarray(list(gflops), dtype=np.float64)
    if d.shape != g.shape or d.size < 2:
        raise ValueError(f"need matched sweeps of >= 2 points, got "
                         f"{d.size} vs {g.size}")
    if np.any(g <= 0) or np.unique(d).size < 2:
        return float(max(g.max(), 1e-9)), 0.0
    slope, intercept = np.polyfit(1.0 / d, 1.0 / g, 1)
    if intercept <= 0 or slope < 0:
        # Throughput not saturating (or decreasing with d): the model's
        # asymptote is unconstrained; report the best measurement flat.
        return float(g.max()), 0.0
    return float(1.0 / intercept), float(slope / intercept)


@dataclasses.dataclass(frozen=True)
class FormatCalibration:
    """Fitted ceiling for one (format, backend, precision) on one host."""

    format: str
    backend: str
    peak_fraction: float
    d_half: float
    sustained_gflops: float           # fitted asymptote, useful GFLOP/s
    useful_fraction: float            # of the calibration matrix
    measured: Dict[int, float]        # d -> measured useful GFLOP/s
    #: Storage precision token the sweep ran at ("f32i32" default keeps
    #: files saved before the precision axis loading cleanly).
    precision: str = "f32i32"


@dataclasses.dataclass(frozen=True)
class Calibration:
    """A full calibration run: per-format ceilings + provenance."""

    hardware: str                     # HardwareSpec.name
    fingerprint: str                  # HardwareSpec.fingerprint()
    backend: str
    entries: Tuple[FormatCalibration, ...]
    #: ``repro.kernels.registry.REGISTRY_VERSION`` at fit time; 0 marks
    #: files saved before versioning existed.  ``staleness_note`` flags
    #: calibrations predating the active kernel set.
    registry_version: int = 0

    def efficiency(self, precision: str = "f32i32"
                   ) -> Dict[str, Tuple[float, float]]:
        """The ``format -> (peak_fraction, d_half)`` ceiling table.

        ``precision`` selects dtype-specific fits: a format's entry for
        the requested token wins; formats calibrated only at fp32 fall
        back to that fit (operand rounding barely moves the *compute*
        ceiling — what a reduced precision changes is the bandwidth
        roofline, which the dispatcher sizes separately).
        """
        out = {e.format: (e.peak_fraction, e.d_half)
               for e in self.entries if e.precision == "f32i32"}
        if precision != "f32i32":
            out.update({e.format: (e.peak_fraction, e.d_half)
                        for e in self.entries if e.precision == precision})
        return out

    def summary(self) -> str:
        """Render the fitted ceilings as a human-readable table."""
        lines = [f"Calibration({self.hardware}, fp={self.fingerprint}, "
                 f"backend={self.backend})"]
        for e in self.entries:
            lines.append(
                f"  {e.format:8s} {e.precision:7s} "
                f"peak_fraction={e.peak_fraction:.4f} "
                f"d_half={e.d_half:6.1f}  "
                f"(sustained {e.sustained_gflops:.2f} GF/s useful, "
                f"useful_fraction {e.useful_fraction:.3f})")
        return "\n".join(lines)


class CalibrationStore:
    """Persistence for :class:`Calibration` results, one file per
    (host, backend).

    Files live under ``$REPRO_CALIBRATION_DIR`` (or
    ``~/.cache/repro/calibrations``) as
    ``<HardwareSpec.name>-<backend>.json`` — jax and pallas ceilings for
    the same host describe different implementations and must not
    overwrite or answer for each other.  ``load`` validates both the
    stored fingerprint against the active spec and the stored backend
    against the requested one: any mismatch returns ``None`` so callers
    fall back to the default ceilings.
    """

    def __init__(self, root: Optional[os.PathLike] = None):
        """Open (without touching the filesystem) the store at ``root``.

        Args:
            root: directory for the JSON files; defaults to
                ``$REPRO_CALIBRATION_DIR`` or ``~/.cache/repro/calibrations``.
        """
        if root is None:
            root = os.environ.get("REPRO_CALIBRATION_DIR") or (
                pathlib.Path.home() / ".cache" / "repro" / "calibrations")
        self.root = pathlib.Path(root)

    def path_for(self, hw: HardwareSpec,
                 backend: str = "jax") -> pathlib.Path:
        """The JSON path holding ``hw``'s calibration for ``backend``."""
        return self.root / f"{hw.name}-{backend}.json"

    def save(self, cal: Calibration) -> pathlib.Path:
        """Write ``cal`` (creating the store directory) and return the path."""
        self.root.mkdir(parents=True, exist_ok=True)
        path = self.root / f"{cal.hardware}-{cal.backend}.json"
        payload = dataclasses.asdict(cal)
        payload["saved_unix"] = time.time()
        path.write_text(json.dumps(payload, indent=2), encoding="utf-8")
        return path

    def load(self, hw: HardwareSpec,
             backend: str = "jax") -> Optional[Calibration]:
        """Read the calibration for ``(hw, backend)``; None when absent
        or stale.

        Stale means the stored fingerprint differs from
        ``hw.fingerprint()`` (fitted against a different compute
        identity) or the stored backend differs from the requested one
        (fitted against a different kernel implementation); either way
        the calibration must not be applied.
        """
        path = self.path_for(hw, backend)
        if not path.is_file():
            return None
        try:
            payload = json.loads(path.read_text(encoding="utf-8"))
        except (OSError, json.JSONDecodeError):
            return None
        if payload.get("fingerprint") != hw.fingerprint():
            return None
        if payload.get("backend", "jax") != backend:
            return None
        entries = tuple(
            FormatCalibration(
                format=e["format"], backend=e["backend"],
                peak_fraction=float(e["peak_fraction"]),
                d_half=float(e["d_half"]),
                sustained_gflops=float(e["sustained_gflops"]),
                useful_fraction=float(e["useful_fraction"]),
                measured={int(k): float(v)
                          for k, v in e["measured"].items()},
                precision=e.get("precision", "f32i32"))
            for e in payload.get("entries", ()))
        return Calibration(hardware=payload["hardware"],
                           fingerprint=payload["fingerprint"],
                           backend=payload.get("backend", "jax"),
                           entries=entries,
                           registry_version=int(
                               payload.get("registry_version", 0)))

    def staleness_note(self, hw: HardwareSpec,
                       backend: str = "jax") -> Optional[str]:
        """One-line staleness warning for ``(hw, backend)``, or ``None``.

        Two conditions earn a note (both mean the persisted numbers do
        not describe what is about to run): the stored fingerprint does
        not match the active spec (``load`` already refuses it — this
        surfaces *why* the dispatcher fell back to defaults), or the
        calibration was fitted against an older kernel registry version
        than the one registered now.  A missing file is not stale:
        defaults are then the intended behavior.
        """
        path = self.path_for(hw, backend)
        if not path.is_file():
            return None
        try:
            payload = json.loads(path.read_text(encoding="utf-8"))
        except (OSError, json.JSONDecodeError):
            return (f"calibration file {path.name} is unreadable; using "
                    f"default ceilings (re-run benchmarks/run.py "
                    f"--calibrate)")
        if payload.get("fingerprint") != hw.fingerprint():
            return (f"calibration {path.name} was fitted for fingerprint "
                    f"{payload.get('fingerprint')}, active spec is "
                    f"{hw.fingerprint()}; using default ceilings (re-run "
                    f"benchmarks/run.py --calibrate)")
        from repro.kernels import registry
        stored = int(payload.get("registry_version", 0))
        if stored < registry.REGISTRY_VERSION:
            return (f"calibration {path.name} predates kernel registry "
                    f"v{registry.REGISTRY_VERSION} (fitted at "
                    f"v{stored}); ceilings may describe retired kernels "
                    f"(re-run benchmarks/run.py --calibrate)")
        return None


def _calibration_matrices(scale: int, bcsr_block: int) -> Dict[str, object]:
    """One structure-matched COOMatrix generator thunk per format.

    Each format gets the structure it exists for, sized to clear the
    dispatch policy gates (BCSR divisibility + dense blocks, DIA band
    width, ELL balanced degrees).
    """
    from repro.core import patterns
    n = 2 ** scale
    t = bcsr_block
    return {
        "csr": lambda: patterns.erdos_renyi(n, 8, seed=11),
        "ell": lambda: patterns.erdos_renyi(n, 8, seed=12),
        "bcsr": lambda: patterns.blocked(
            n, t=t, num_blocks=max(2 * (n // t), 1),
            nnz_per_block=int(t * t * 0.8), seed=13),
        "dia": lambda: patterns.banded(n, 3, fill=1.0, seed=14),
        # The scale-free tier calibrates on the structure it targets:
        # skewed degree distributions with hub rows/columns.
        "binned": lambda: patterns.scale_free(n, 8, alpha=2.05, seed=15),
        "rowsplit": lambda: patterns.scale_free(n, 8, alpha=2.2, seed=16),
        "ell_coo": lambda: patterns.scale_free(n, 8, seed=17),
    }


def _best_of(fn, repeats: int) -> float:
    import jax
    jax.block_until_ready(fn())          # warm-up: jit compile, caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate(hw: HardwareSpec, *, backend: str = "jax",
              formats: Optional[Sequence[str]] = None,
              d_values: Sequence[int] = DEFAULT_D_VALUES,
              scale: int = 9, repeats: int = 3, bcsr_block: int = 32,
              precisions: Sequence[str] = ("f32i32",),
              store: Optional[CalibrationStore] = None) -> Calibration:
    """Measure and fit the per-format compute ceilings on this host.

    For each format, runs the registered kernel (through the dispatcher's
    executor, so the measured path is the served path) on a
    structure-matched matrix across ``d_values``, fits
    ``(peak_fraction, d_half)`` (see :func:`fit_ceiling`), and optionally
    persists the result.

    Args:
        hw: the hardware spec the ceilings are expressed against
            (``peak_fraction`` is relative to ``hw.peak_flops``).
        backend: ``"jax"`` or ``"pallas"`` — which registered kernels to
            calibrate.  Off-TPU, pallas interpret-mode timings measure
            the interpreter, not the kernel; calibrate ``"jax"`` there.
        formats: formats to sweep; defaults to every format registered
            under ``backend`` that is also a dispatch format.
        d_values: dense widths of the sweep.
        scale: matrix dimension exponent (n = 2**scale).
        repeats: min-of-N timing repeats per cell.
        bcsr_block: BCSR block edge for the blocked calibration matrix.
        precisions: precision tokens to fit per format (each a separate
            :class:`FormatCalibration` entry); combos a kernel spec does
            not support, or that this matrix cannot legally pack (int16
            extent), are skipped.  Default fits fp32 only.
        store: when given, ``store.save`` the result before returning.

    Returns:
        The fitted :class:`Calibration`.
    """
    from repro import sparse
    from repro.kernels import registry

    if formats is None:
        formats = [f for f in sparse.FORMATS
                   if f in registry.formats_for(backend)]
    gens = _calibration_matrices(scale, bcsr_block)
    unknown = sorted(set(formats) - set(gens))
    if unknown:
        raise ValueError(f"no calibration matrix for formats {unknown}")

    # Ceilings must not influence the measurement: strategies are forced,
    # and the dispatcher is isolated from any existing calibration file.
    disp = sparse.Dispatcher(hardware=hw, backend=backend,
                             bcsr_block=bcsr_block, calibration=False)
    entries = []
    for fmt in formats:
        spec = registry.get(fmt, backend)
        for prec in precisions:
            if prec not in spec.supported_precisions:
                continue
            m = gens[fmt]()
            rng = np.random.default_rng(7)
            measured: Dict[int, float] = {}
            useful_fraction = 1.0
            try:
                for d in d_values:
                    import jax.numpy as jnp
                    b = jnp.asarray(
                        rng.normal(size=(m.n, d)).astype(np.float32))
                    plan = disp.plan(m, d, strategy=fmt, precision=prec)
                    useful_fraction = plan.candidate(fmt).useful_fraction
                    run = disp.executor(m, plan)
                    dt = _best_of(lambda run=run, b=b: run(b), repeats)
                    measured[int(d)] = 2.0 * m.nnz * d / dt / 1e9
            except ValueError:
                # e.g. int16 extent illegal for this matrix: skip the
                # combo, the fp32 fit still answers for the format.
                continue
            g_inf, d_half = fit_ceiling(list(measured),
                                        list(measured.values()))
            lo, hi = PEAK_FRACTION_RANGE
            peak_fraction = float(np.clip(
                g_inf * 1e9 / (hw.peak_flops * max(useful_fraction, 1e-9)),
                lo, hi))
            d_half = float(np.clip(d_half, *D_HALF_RANGE))
            entries.append(FormatCalibration(
                format=fmt, backend=backend, peak_fraction=peak_fraction,
                d_half=d_half, sustained_gflops=g_inf,
                useful_fraction=useful_fraction, measured=measured,
                precision=prec))
    cal = Calibration(hardware=hw.name, fingerprint=hw.fingerprint(),
                      backend=backend, entries=tuple(entries),
                      registry_version=registry.REGISTRY_VERSION)
    if store is not None:
        store.save(cal)
    return cal
