"""Sparse-pattern generators mirroring the paper's matrix classes (Table III).

The paper evaluates four structural regimes drawn from SuiteSparse plus
synthetic generators.  SuiteSparse is unavailable offline, so we generate each
regime synthetically with the same statistical definitions the paper's models
assume:

  random      Erdos-Renyi, ``er_<log2 n>_<avg_deg>`` (the paper's own generator)
  diagonal    banded matrices, incl. the paper's ``ideal_diagonal`` (1 nnz/row)
  blocked     t x t blocks placed uniformly, D nonzeros per block on average
  scale_free  power-law degree distribution p(k) ~ k^-alpha (configuration-style)

Everything is plain numpy COO -> sorted CSR arrays; no scipy dependency.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class COOMatrix:
    """Deduplicated, row-major-sorted COO pattern with values."""

    n: int
    rows: np.ndarray       # int32 [nnz]
    cols: np.ndarray       # int32 [nnz]
    vals: np.ndarray       # float [nnz]
    pattern: str           # generator regime tag
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    def row_ptr(self) -> np.ndarray:
        """CSR row pointers (int32 [n+1])."""
        counts = np.bincount(self.rows, minlength=self.n)
        return np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)


def _finalize(n: int, rows: np.ndarray, cols: np.ndarray, pattern: str,
              rng: np.random.Generator, meta: dict | None = None) -> COOMatrix:
    """Clip, deduplicate, sort row-major, and attach random values.

    Deduplication means a generator can deliver fewer nonzeros than it
    drew (birthday collisions); the *achieved* density is therefore
    recorded in ``meta`` (``achieved_nnz`` / ``achieved_avg_degree``) so
    downstream consumers — suite labels, roofline inputs, the corpus
    fitter — never have to assume the nominal request was met.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    keep = (rows >= 0) & (rows < n) & (cols >= 0) & (cols < n)
    rows, cols = rows[keep], cols[keep]
    # Dedup via linear index.
    lin = rows * n + cols
    lin = np.unique(lin)
    rows = (lin // n).astype(np.int32)
    cols = (lin % n).astype(np.int32)
    vals = rng.uniform(0.5, 1.5, size=rows.shape[0]).astype(np.float64)
    meta = dict(meta or {})
    meta["achieved_nnz"] = int(rows.shape[0])
    meta["achieved_avg_degree"] = rows.shape[0] / max(n, 1)
    return COOMatrix(n=n, rows=rows, cols=cols, vals=vals, pattern=pattern,
                     meta=meta)


def erdos_renyi(n: int, avg_degree: float, seed: int = 0) -> COOMatrix:
    """Uniform random sparsity: the paper's ``er_*`` matrices.

    Delivers *exactly* ``round(n * avg_degree)`` nonzeros (capped at the
    dense n^2): duplicate draws are resampled until the target is met,
    so suite labels like ``er_16_20`` and the roofline's nnz inputs mean
    what they say.  (The naive draw-then-dedup loses ~avg_degree/(2n) of
    its entries to birthday collisions — measurable at benchmark scales.)
    """
    rng = np.random.default_rng(seed)
    target = min(int(round(n * avg_degree)), n * n)
    lin = np.unique(rng.integers(0, n * n, size=target))
    while lin.size < target:
        extra = rng.integers(0, n * n, size=2 * (target - lin.size) + 16)
        lin = np.union1d(lin, extra)
    if lin.size > target:
        # Unbiased truncation: np.unique sorted the draws, so keeping a
        # prefix would skew the pattern toward low row indices.
        lin = np.sort(rng.choice(lin, size=target, replace=False))
    return _finalize(n, lin // n, lin % n, "random", rng,
                     {"avg_degree": avg_degree})


def banded(n: int, bandwidth: int = 1, fill: float = 1.0,
           seed: int = 0) -> COOMatrix:
    """Diagonal/banded sparsity.

    bandwidth=1, fill=1 reproduces ``ideal_diagonal`` (exactly one nonzero per
    row on the main diagonal).  Larger bandwidths emulate FEM/DFT-style bands;
    ``fill`` < 1 drops entries at random to mimic imperfect bands (rajat31).
    """
    rng = np.random.default_rng(seed)
    offsets = np.arange(-(bandwidth - 1), bandwidth)
    if bandwidth == 1:
        offsets = np.array([0])
    rows_list, cols_list = [], []
    for off in offsets:
        r = np.arange(max(0, -off), min(n, n - off))
        c = r + off
        if fill < 1.0:
            keep = rng.uniform(size=r.shape[0]) < fill
            r, c = r[keep], c[keep]
        rows_list.append(r)
        cols_list.append(c)
    rows = np.concatenate(rows_list)
    cols = np.concatenate(cols_list)
    return _finalize(n, rows, cols, "diagonal", rng,
                     {"bandwidth": bandwidth, "fill": fill})


def blocked(n: int, t: int, num_blocks: int, nnz_per_block: float,
            seed: int = 0, diagonal_bias: float = 0.5) -> COOMatrix:
    """Block-structured sparsity: ``num_blocks`` t x t blocks, each with an
    average of ``nnz_per_block`` (the paper's D) nonzeros placed uniformly
    inside the block.

    ``diagonal_bias`` fraction of the blocks hug the diagonal (road-network
    style locality); the remainder are uniform.
    """
    rng = np.random.default_rng(seed)
    nb = n // t
    if nb == 0:
        raise ValueError("block size exceeds matrix size")
    num_blocks = min(num_blocks, nb * nb)
    n_diag = int(num_blocks * diagonal_bias)
    # Diagonal-ish blocks: near the main block diagonal.
    bi = rng.integers(0, nb, size=n_diag)
    bj = np.clip(bi + rng.integers(-1, 2, size=n_diag), 0, nb - 1)
    # Uniform blocks for the rest.
    bi2 = rng.integers(0, nb, size=num_blocks - n_diag)
    bj2 = rng.integers(0, nb, size=num_blocks - n_diag)
    block_i = np.concatenate([bi, bi2])
    block_j = np.concatenate([bj, bj2])
    # Dedup block coordinates.
    blin = np.unique(block_i.astype(np.int64) * nb + block_j)
    block_i = (blin // nb).astype(np.int64)
    block_j = (blin % nb).astype(np.int64)
    N = block_i.shape[0]
    per_block = rng.poisson(nnz_per_block, size=N).clip(1, t * t)
    total = int(per_block.sum())
    block_of_entry = np.repeat(np.arange(N), per_block)
    rr = rng.integers(0, t, size=total)
    cc = rng.integers(0, t, size=total)
    rows = block_i[block_of_entry] * t + rr
    cols = block_j[block_of_entry] * t + cc
    return _finalize(n, rows, cols, "blocked", rng,
                     {"t": t, "num_blocks": N, "D": float(nnz_per_block)})


def scale_free(n: int, avg_degree: float, alpha: float = 2.2,
               seed: int = 0, k_min: int = 1,
               hub_fraction: float = 0.001) -> COOMatrix:
    """Power-law (scale-free) sparsity matching the paper's hub model.

    Row (out-)degrees follow a truncated power law p(k) ~ k^-alpha.
    Columns realize the appendix's hub structure explicitly: the top
    ``hub_fraction`` of nodes receive nnz * f^((alpha-2)/(alpha-1)) of the
    edges (Eq. 5); remaining edges land uniformly.  This makes the B-row
    reuse the paper's Eq. 6 assumes actually measurable.
    """
    rng = np.random.default_rng(seed)
    # Row degrees: inverse-CDF power law, truncated and rescaled.
    u = rng.uniform(size=n)
    kmax = max(n // 4, k_min + 1)
    k = k_min * u ** (-1.0 / (alpha - 1.0))
    k = np.minimum(k, kmax)
    k = np.maximum(k * (avg_degree * n / k.sum()), 0).astype(np.int64)
    total = int(k.sum())
    rows = np.repeat(np.arange(n), k)
    # Columns: hub mass per the appendix derivation.
    from repro.core.sparsity_models import hub_edge_fraction
    n_hub = max(1, int(n * hub_fraction))
    hub_mass = hub_edge_fraction(alpha, hub_fraction)
    is_hub_edge = rng.uniform(size=total) < hub_mass
    # Hub popularity is itself heavy-tailed (zipf over the hub set).
    hub_ranks = rng.zipf(1.5, size=total) % n_hub
    hub_cols = hub_ranks * (n // n_hub)          # spread hubs over ids
    uniform_cols = rng.integers(0, n, size=total)
    cols = np.where(is_hub_edge, hub_cols, uniform_cols)
    return _finalize(n, rows, cols, "scale_free", rng,
                     {"alpha": alpha, "avg_degree": avg_degree,
                      "hub_fraction": hub_fraction})


def fit_generator(report, *, n: int | None = None,
                  seed: int = 0) -> COOMatrix:
    """Synthesize a matrix fitted to a real matrix's measured statistics.

    The corpus layer's bridge back to the generators: given the
    :class:`repro.core.classify.StructureReport` of a real (e.g. vendored
    or SuiteSparse) matrix, return a synthetic ``COOMatrix`` of the same
    regime whose generator parameters are read off the report —

      diagonal    bandwidth/fill from the measured band fraction and
                  average degree
      blocked     probe block size t, block count N, and block density D
                  straight from the report's block statistics
      scale_free  Hill-estimated alpha (clamped to the paper's modeled
                  range) at the measured average degree
      random      Erdos-Renyi at the measured average degree

    Args:
        report: a ``StructureReport`` from ``classify(real_matrix)``.
        n: optional size override — scale the fitted structure up or down
            (block counts scale proportionally; densities are preserved).
        seed: generator seed.

    Returns:
        A synthetic ``COOMatrix`` with ``meta["fitted_from"]`` recording
        the source statistics the parameters were read from.
    """
    stats = report.stats
    src_n = int(stats["n"])
    n = int(n or src_n)
    avg_degree = stats["nnz"] / max(src_n, 1)
    if report.regime == "diagonal":
        # avg_degree nonzeros per row spread over a (2*bw - 1)-wide band.
        bw = max(1, int(round((avg_degree + 1) / 2)))
        width = 1 if bw == 1 else 2 * bw - 1
        fill = float(np.clip(avg_degree / width, 0.05, 1.0))
        m = banded(n, bw, fill=fill, seed=seed)
    elif report.regime == "blocked":
        t = int(stats.get("block_t", 64))
        t = min(t, n)
        num_blocks = max(1, int(round(stats.get("block_N", 1) * n / src_n)))
        m = blocked(n, t=t, num_blocks=num_blocks,
                    nnz_per_block=max(stats.get("block_D", 1.0), 1.0),
                    seed=seed)
    elif report.regime == "scale_free":
        alpha = report.params.get("alpha", stats.get("alpha_hill", 2.2))
        alpha = float(np.clip(alpha, 2.05, 2.95))
        hub_fraction = report.params.get("hub_fraction", 0.001)
        m = scale_free(n, max(avg_degree, 1.0), alpha=alpha, seed=seed,
                       hub_fraction=hub_fraction)
    else:
        m = erdos_renyi(n, max(avg_degree, 1.0), seed=seed)
    fitted_from = {"regime": report.regime, "n": src_n,
                   "nnz": int(stats["nnz"]),
                   "band_fraction": stats.get("band_fraction"),
                   "alpha_hill": stats.get("alpha_hill"),
                   "block_D": stats.get("block_D"),
                   "block_z_emp": stats.get("block_z_emp")}
    return dataclasses.replace(m, meta={**m.meta,
                                        "fitted_from": fitted_from})


#: The reduced-scale reproduction suite standing in for the paper's Table III.
#: Names follow the paper's convention; sizes are scaled to container memory
#: while staying far larger than host caches (the paper's selection criterion).
def paper_suite(scale: int = 16):
    """Return the dict of generator thunks for the benchmark suite.

    ``scale`` is log2(n).  At the default 2**16 = 65,536 rows the working sets
    (B, C at d=64: 64 MB) exceed this host's LLC, preserving the paper's
    out-of-cache regime.
    """
    n = 2 ** scale
    return {
        # Random (paper: er_22_{1,10,20})
        f"er_{scale}_1": lambda: erdos_renyi(n, 1, seed=1),
        f"er_{scale}_10": lambda: erdos_renyi(n, 10, seed=2),
        f"er_{scale}_20": lambda: erdos_renyi(n, 20, seed=3),
        # Diagonal (paper: ideal_diagonal_22, rajat31)
        f"ideal_diagonal_{scale}": lambda: banded(n, 1, seed=4),
        f"band_{scale}_5": lambda: banded(n, 5, fill=0.8, seed=5),
        # Blocked (paper: road_usa, asia_osm, ...: mesh-local structure)
        f"blocked_{scale}_d64": lambda: blocked(
            n, t=64, num_blocks=max(1, n // 32), nnz_per_block=40, seed=6),
        # FEM-style dense small blocks (stiffness matrices): the regime
        # where dense-block storage (CSB/BCSR) genuinely pays off.
        f"fem_{scale}_t32": lambda: blocked(
            n, t=32, num_blocks=max(1, n // 16), nnz_per_block=320, seed=7),
        # Scale-free (paper: com-Orkut, com-LiveJournal, uk-2002)
        f"powerlaw_{scale}_22": lambda: scale_free(n, 16, alpha=2.2, seed=8),
        f"powerlaw_{scale}_28": lambda: scale_free(n, 16, alpha=2.8, seed=9),
        # High skew (alpha -> 2): the heaviest hubs the generator makes —
        # the regime PR 8's binned/rowsplit kernels target.
        f"powerlaw_{scale}_205": lambda: scale_free(
            n, 16, alpha=2.05, seed=10),
    }


def block_diagonal(n: int, t: int = 64, seed: int = 0) -> COOMatrix:
    """Dense t x t blocks on the diagonal: the MoE expert-dispatch shape.

    ``repro.models.moe`` routes tokens into per-expert capacity buckets,
    which makes the expert FFN exactly this operator (the best case of the
    blocked regime: z = t, MXU utilization 1).  Requires ``t`` to divide
    ``n``.
    """
    if n % t != 0:
        raise ValueError(f"n must be a multiple of t={t}, got {n}")
    nb = n // t
    rng = np.random.default_rng(seed)
    base = np.repeat(np.arange(nb, dtype=np.int64) * t, t * t)
    rr = np.tile(np.repeat(np.arange(t), t), nb)
    cc = np.tile(np.tile(np.arange(t), t), nb)
    rows = (base + rr).astype(np.int32)
    cols = (base + cc).astype(np.int32)
    vals = rng.uniform(0.5, 1.5, size=rows.shape[0]).astype(np.float64)
    return COOMatrix(n=n, rows=rows, cols=cols, vals=vals,
                     pattern="blocked",
                     meta={"t": t, "num_blocks": nb, "D": float(t * t)})


def serving_suite(n: int):
    """The four paper structures at serving scale (generator thunks).

    The single registry shared by the streamed-dispatch surfaces —
    ``repro.launch.serve --spmm-stream`` and ``benchmarks/stream.py`` —
    so the serving demo and the CI-gated suite measure the same
    operators.
    """
    return {
        "moe-block": lambda: block_diagonal(n, 64, seed=0),
        "banded": lambda: banded(n, 5, fill=0.9, seed=5),
        "scale-free": lambda: scale_free(n, 16, alpha=2.2, seed=8),
        "uniform": lambda: erdos_renyi(n, 10, seed=2),
    }
