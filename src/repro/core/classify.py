"""Sparsity-structure classifier: recover the paper's regime + model
parameters from a concrete matrix.

The paper groups matrices by hand; a deployable system needs to *detect* the
regime so the right roofline model (and the right kernel) is selected
automatically.  The detector computes cheap structural statistics on the COO
pattern and scores each regime:

  diagonal    fraction of nnz within a small band of the main diagonal
  blocked     block-occupancy statistics at a probe block size t
              (paper's D = nnz/N and z = occupied columns per block)
  scale_free  tail heaviness of the degree distribution (Hill estimator of
              alpha, plus Gini coefficient of degree mass)
  random      the fallback when no structure is detected

Returns the regime, the fitted parameters for the matching AI model, and the
full statistics so callers can audit the decision.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.patterns import COOMatrix
from repro.core import sparsity_models as sm


@dataclasses.dataclass(frozen=True)
class StructureReport:
    regime: str
    params: dict
    stats: dict

    def traffic(self, d: int, **overrides) -> sm.TrafficBreakdown:
        """Arithmetic-intensity estimate for this matrix at dense width d."""
        kwargs = dict(self.params)
        kwargs.update(overrides)
        n = self.stats["n"]
        nnz = self.stats["nnz"]
        return sm.arithmetic_intensity(self.regime, n, nnz, d, **kwargs)


def band_fraction(m: COOMatrix, rel_bandwidth: float = 0.01) -> float:
    """Fraction of nonzeros within a small band of the main diagonal.

    The window is ``rel_bandwidth * n`` wide with an absolute floor of
    ``min(8, n // 8)``: at corpus scales (n of a few hundred) a purely
    relative window is 1–2 entries wide and misses real FEM/DFT bands
    entirely — a bandwidth-5 matrix at n=224 measured 0.33 here and
    fell through to ``random``.
    """
    if m.nnz == 0:
        return 0.0
    w = max(1, int(m.n * rel_bandwidth), min(8, m.n // 8))
    return float(np.mean(np.abs(m.rows.astype(np.int64) - m.cols) < w))


def block_stats(m: COOMatrix, t: int = 64) -> dict:
    """Paper Section III-C statistics at probe block size t.

    Returns N (nonzero blocks), D (nnz per block), z_emp (measured occupied
    columns per block) and z_model (the paper's t(1-e^{-D/t}) prediction).
    """
    bi = m.rows.astype(np.int64) // t
    bj = m.cols.astype(np.int64) // t
    nb = (m.n + t - 1) // t
    blin = bi * nb + bj
    uniq_blocks, counts = np.unique(blin, return_counts=True)
    N = int(uniq_blocks.shape[0])
    D = m.nnz / max(N, 1)
    # Occupied columns per block: unique (block, col-within-block) pairs.
    col_in_block = (m.cols.astype(np.int64) % t)
    pair = blin * t + col_in_block
    occupied = np.unique(pair).shape[0]
    z_emp = occupied / max(N, 1)
    return {
        "t": t, "N": N, "D": float(D), "z_emp": float(z_emp),
        "z_model": sm.expected_occupied_columns(t, D),
        "block_fill": float(D / (t * t)),
    }


#: Minimum positive-degree sample for a meaningful Hill fit.  Below it
#: the estimator returns ``inf`` ("no detectable heavy tail") *by
#: design* — the scale-free gate in :func:`classify` then cannot fire,
#: so tiny matrices fall through to the block/random ladder instead of
#: being tail-classified off a handful of degrees.
HILL_MIN_DEGREES = 16


def hill_alpha(degrees: np.ndarray, tail_fraction: float = 0.05) -> float:
    """Hill estimator of the power-law exponent on the degree tail.

    Returns ``inf`` — explicitly meaning *no detectable heavy tail* —
    when the estimate is not meaningful: fewer than
    :data:`HILL_MIN_DEGREES` positive degrees, or a flat tail
    (``x_(k)`` equals the tail values, e.g. uniform or banded degree
    vectors).  Callers gate on a finite range (``classify`` uses
    ``1.5 < alpha < 3.5``), so ``inf`` always reads as "not
    scale-free".

    The tail index ``k`` is clamped to at most half the sample: the old
    ``k = min(k, size - 1)`` clamp let ``x_(k)`` be the *minimum*
    degree on small vectors, which silently degenerated the estimator
    (tail == whole distribution) and returned ``inf`` for genuinely
    skewed small matrices — the corpus-audit misclassification this
    clamp fixes.
    """
    deg = degrees[degrees > 0]
    if deg.size < HILL_MIN_DEGREES:
        return float("inf")
    deg = np.sort(deg)[::-1].astype(np.float64)
    k = int(np.clip(max(8, int(deg.size * tail_fraction)),
                    1, deg.size // 2))
    tail = deg[:k]
    x_k = deg[k]
    if x_k <= 0:
        return float("inf")
    hill = np.mean(np.log(tail / x_k))
    if hill <= 0:
        return float("inf")
    return 1.0 + 1.0 / float(hill)


def hub_dominance(degrees: np.ndarray, top_fraction: float = 0.01) -> float:
    """Edge share of the top ``top_fraction`` of nodes, relative to uniform.

    1.0 means the heaviest 1% of nodes own exactly their uniform share
    of the edges; scale-free hub structure measures an order of
    magnitude higher.  Unlike the Gini coefficient this statistic does
    not wash out at small n (where the power-law's ``kmax`` truncation
    compresses the whole distribution): the corpus-scale matrices that
    motivated it measure Gini ~0.49 but dominance ~9-13x.
    """
    total = degrees.sum()
    if total == 0:
        return 0.0
    top = max(1, int(np.ceil(degrees.size * top_fraction)))
    share = np.sort(degrees)[::-1][:top].sum() / total
    return float(share / (top / degrees.size))


def degree_gini(degrees: np.ndarray) -> float:
    """Gini coefficient of the degree distribution (0 = uniform, 1 = hub)."""
    d = np.sort(degrees.astype(np.float64))
    if d.sum() == 0:
        return 0.0
    n = d.size
    cum = np.cumsum(d)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)


def classify(m: COOMatrix, probe_t: int = 64) -> StructureReport:
    """Detect the sparsity regime and fit the corresponding model params.

    Degree-tail statistics are computed on *both* axes and the
    heavier-tailed side (by Gini) drives the scale-free gate: hub
    structure lives in whichever axis concentrates the edges, and a
    column-hub matrix (e.g. the transpose of a scale-free web graph)
    has perfectly uniform row degrees — row-only statistics would let
    it fall through to ``random`` and pick the wrong AI model.  Both
    sides are recorded (``row_gini`` / ``col_gini`` / ``tail_axis``)
    so the decision is auditable.
    """
    row_deg = np.bincount(m.rows, minlength=m.n)
    col_deg = np.bincount(m.cols, minlength=m.n)
    row_gini, col_gini = degree_gini(row_deg), degree_gini(col_deg)
    tail_axis = "col" if col_gini > row_gini else "row"
    tail_deg = col_deg if tail_axis == "col" else row_deg
    bstats = block_stats(m, probe_t)
    stats = {
        "n": m.n,
        "nnz": m.nnz,
        "avg_degree": m.nnz / m.n,
        "band_fraction": band_fraction(m),
        "alpha_hill": hill_alpha(tail_deg),
        "degree_gini": degree_gini(tail_deg),
        "hub_dominance": hub_dominance(tail_deg),
        "row_gini": row_gini,
        "col_gini": col_gini,
        "tail_axis": tail_axis,
        **{f"block_{k}": v for k, v in bstats.items()},
    }

    # --- Decision ladder (most-specific structure first). ---
    if stats["band_fraction"] > 0.95 and stats["avg_degree"] < probe_t:
        return StructureReport("diagonal", {}, stats)

    # Scale-free gate: a heavy tail (finite Hill alpha in the paper's
    # modeled band) concentrated either globally (Gini) or in explicit
    # hubs (dominance — the small-matrix signal: at corpus scales the
    # kmax truncation keeps Gini below the 0.55 cut while the top 1% of
    # nodes still own ~10x their uniform edge share).
    gini = stats["degree_gini"]
    alpha = stats["alpha_hill"]
    if (gini > 0.55 or stats["hub_dominance"] > 7.0) and 1.5 < alpha < 3.5:
        return StructureReport(
            "scale_free", {"alpha": float(min(max(alpha, 2.05), 2.95)),
                           "hub_fraction": 0.001}, stats)

    # Blocked: the measured occupancy is far denser than a random pattern
    # of the same nnz would produce (random => N ~ min(nnz, nb^2), D ~ 1).
    # Small matrices re-probe at probe_t // 2: with fewer than ~16 block
    # rows at the primary probe the occupancy contrast is statistically
    # meaningless (a 256-row matrix has 16 probe-64 blocks total), which
    # sent every corpus-scale blocked matrix to ``random``.
    probes = [probe_t]
    if m.n < 16 * probe_t and probe_t >= 4:
        probes.append(probe_t // 2)
    for t in probes:
        bs = bstats if t == probe_t else block_stats(m, t)
        nb = (m.n + t - 1) // t
        expected_random_blocks = min(m.nnz, nb * nb)
        if bs["N"] < 0.5 * expected_random_blocks and bs["D"] > 4.0:
            stats.update({f"block_{k}": v for k, v in bs.items()})
            return StructureReport(
                "blocked", {"t": t, "num_blocks": bs["N"]}, stats)

    return StructureReport("random", {}, stats)
