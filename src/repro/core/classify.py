"""Sparsity-structure classifier: recover the paper's regime + model
parameters from a concrete matrix.

The paper groups matrices by hand; a deployable system needs to *detect* the
regime so the right roofline model (and the right kernel) is selected
automatically.  The detector computes cheap structural statistics on the COO
pattern and scores each regime:

  diagonal    fraction of nnz within a small band of the main diagonal
  blocked     block-occupancy statistics at a probe block size t
              (paper's D = nnz/N and z = occupied columns per block)
  scale_free  tail heaviness of the degree distribution (Hill estimator of
              alpha, plus Gini coefficient of degree mass)
  random      the fallback when no structure is detected

Returns the regime, the fitted parameters for the matching AI model, and the
full statistics so callers can audit the decision.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.patterns import COOMatrix
from repro.core import sparsity_models as sm


@dataclasses.dataclass(frozen=True)
class StructureReport:
    regime: str
    params: dict
    stats: dict

    def traffic(self, d: int, **overrides) -> sm.TrafficBreakdown:
        """Arithmetic-intensity estimate for this matrix at dense width d."""
        kwargs = dict(self.params)
        kwargs.update(overrides)
        n = self.stats["n"]
        nnz = self.stats["nnz"]
        return sm.arithmetic_intensity(self.regime, n, nnz, d, **kwargs)


def band_fraction(m: COOMatrix, rel_bandwidth: float = 0.01) -> float:
    """Fraction of nonzeros within ``rel_bandwidth * n`` of the diagonal."""
    w = max(1, int(m.n * rel_bandwidth))
    return float(np.mean(np.abs(m.rows.astype(np.int64) - m.cols) < w))


def block_stats(m: COOMatrix, t: int = 64) -> dict:
    """Paper Section III-C statistics at probe block size t.

    Returns N (nonzero blocks), D (nnz per block), z_emp (measured occupied
    columns per block) and z_model (the paper's t(1-e^{-D/t}) prediction).
    """
    bi = m.rows.astype(np.int64) // t
    bj = m.cols.astype(np.int64) // t
    nb = (m.n + t - 1) // t
    blin = bi * nb + bj
    uniq_blocks, counts = np.unique(blin, return_counts=True)
    N = int(uniq_blocks.shape[0])
    D = m.nnz / max(N, 1)
    # Occupied columns per block: unique (block, col-within-block) pairs.
    col_in_block = (m.cols.astype(np.int64) % t)
    pair = blin * t + col_in_block
    occupied = np.unique(pair).shape[0]
    z_emp = occupied / max(N, 1)
    return {
        "t": t, "N": N, "D": float(D), "z_emp": float(z_emp),
        "z_model": sm.expected_occupied_columns(t, D),
        "block_fill": float(D / (t * t)),
    }


def hill_alpha(degrees: np.ndarray, tail_fraction: float = 0.05) -> float:
    """Hill estimator of the power-law exponent on the degree tail."""
    deg = degrees[degrees > 0]
    if deg.size < 16:
        return float("inf")
    deg = np.sort(deg)[::-1].astype(np.float64)
    k = max(8, int(deg.size * tail_fraction))
    k = min(k, deg.size - 1)
    tail = deg[:k]
    x_k = deg[k]
    if x_k <= 0:
        return float("inf")
    hill = np.mean(np.log(tail / x_k))
    if hill <= 0:
        return float("inf")
    return 1.0 + 1.0 / float(hill)


def degree_gini(degrees: np.ndarray) -> float:
    """Gini coefficient of the degree distribution (0 = uniform, 1 = hub)."""
    d = np.sort(degrees.astype(np.float64))
    if d.sum() == 0:
        return 0.0
    n = d.size
    cum = np.cumsum(d)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)


def classify(m: COOMatrix, probe_t: int = 64) -> StructureReport:
    """Detect the sparsity regime and fit the corresponding model params."""
    degrees = np.bincount(m.rows, minlength=m.n)
    bstats = block_stats(m, probe_t)
    stats = {
        "n": m.n,
        "nnz": m.nnz,
        "avg_degree": m.nnz / m.n,
        "band_fraction": band_fraction(m),
        "alpha_hill": hill_alpha(degrees),
        "degree_gini": degree_gini(degrees),
        **{f"block_{k}": v for k, v in bstats.items()},
    }

    # --- Decision ladder (most-specific structure first). ---
    if stats["band_fraction"] > 0.95 and stats["avg_degree"] < probe_t:
        return StructureReport("diagonal", {}, stats)

    gini = stats["degree_gini"]
    alpha = stats["alpha_hill"]
    if gini > 0.55 and 1.5 < alpha < 3.5:
        return StructureReport(
            "scale_free", {"alpha": float(min(max(alpha, 2.05), 2.95)),
                           "hub_fraction": 0.001}, stats)

    # Blocked: the measured occupancy is far denser than a random pattern of
    # the same nnz would produce (random => N ~ min(nnz, nb^2), D ~ 1).
    nb = (m.n + probe_t - 1) // probe_t
    expected_random_blocks = min(m.nnz, nb * nb)
    if bstats["N"] < 0.5 * expected_random_blocks and bstats["D"] > 4.0:
        return StructureReport(
            "blocked", {"t": probe_t, "num_blocks": bstats["N"]}, stats)

    return StructureReport("random", {}, stats)
