"""Sparsity-aware arithmetic-intensity models for SpMM (paper Section III).

All formulas model ``C[n,d] = A[n,n] @ B[n,d]`` with A sparse (nnz nonzeros)
and B tall-and-skinny (d << n).

FLOPs are always ``2 * d * nnz`` (one multiply + one add per nonzero per
column, Eq. 1).  The models differ only in the *memory traffic* they charge
for B, which is where sparsity structure enters:

  random      (Eq. 2): every nonzero reloads its row of B — zero reuse.
  diagonal    (Eq. 3): B is loaded exactly once — perfect reuse.
  blocked     (Eq. 4): per t x t block, z = t(1 - e^{-D/t}) occupied columns,
                       with the paper's 1/4 cache-reuse heuristic on B traffic.
  scale-free  (Eq. 6): hub rows of B stay resident; hub edge mass from the
                       appendix power-law derivation, nnz_hub = nnz * f^((a-2)/(a-1)).

Kernel-side variants (outside the paper's numbering) price the scale-free
kernels of PR 8: ``ai_binned`` charges binning traffic (slab reads +
partial-C writes), ``ai_rowsplit`` the windowed-partial scatter of the
merge-path kernel, and ``ai_ell_coo`` the padded-body / COO-tail storage
split of the hybrid layout.

Byte sizes are parameterized: the paper uses fp64 values (8 B) + int32 indices
(4 B); the TPU variants default to bf16/fp32.  The paper's constants are the
defaults so the reproduction benchmarks match the published equations exactly.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class TrafficBreakdown:
    """Bytes moved per operand plus the derived intensity."""

    flops: float
    bytes_a: float
    bytes_b: float
    bytes_c: float
    model: str

    @property
    def total_bytes(self) -> float:
        return self.bytes_a + self.bytes_b + self.bytes_c

    @property
    def ai(self) -> float:
        return self.flops / self.total_bytes


def flops_spmm(nnz: int, d: int) -> float:
    """Eq. 1: 2 FLOPs per nonzero per dense column."""
    return 2.0 * d * nnz


def _traffic_a_csr(n: int, nnz: int, sizeof_val: int, sizeof_idx: int) -> float:
    """CSR: values + column indices + (n+1) row pointers (~12*nnz for fp64/int32)."""
    return nnz * sizeof_val + nnz * sizeof_idx + (n + 1) * sizeof_idx


def _traffic_c(n: int, d: int, sizeof_val: int) -> float:
    return n * d * sizeof_val


def ai_random(n: int, nnz: int, d: int, *, sizeof_val: int = 8,
              sizeof_idx: int = 4) -> TrafficBreakdown:
    """Eq. 2 — worst case / lower bound: no reuse of B at all."""
    return TrafficBreakdown(
        flops=flops_spmm(nnz, d),
        bytes_a=_traffic_a_csr(n, nnz, sizeof_val, sizeof_idx),
        bytes_b=nnz * d * sizeof_val,
        bytes_c=_traffic_c(n, d, sizeof_val),
        model="random",
    )


def ai_diagonal(n: int, nnz: int, d: int, *, sizeof_val: int = 8,
                sizeof_idx: int = 4) -> TrafficBreakdown:
    """Eq. 3 — best case / upper bound: B read exactly once (8nd), C written once.

    The paper folds these into the ``16nd`` term; A costs 12*nnz as in CSR.
    """
    return TrafficBreakdown(
        flops=flops_spmm(nnz, d),
        bytes_a=_traffic_a_csr(n, nnz, sizeof_val, sizeof_idx),
        bytes_b=n * d * sizeof_val,
        bytes_c=_traffic_c(n, d, sizeof_val),
        model="diagonal",
    )


def expected_occupied_columns(t: int, D: float) -> float:
    """z = t * (1 - (1 - 1/t)^D)  ~=  t * (1 - e^{-D/t})  (paper Section III-C).

    The exact binomial form is used for small t; the exponential limit is the
    paper's approximation — both agree to <1% for t >= 32.
    """
    if t <= 0:
        raise ValueError("block size must be positive")
    if D <= 0:
        return 0.0
    return t * (1.0 - math.exp(-D / t))


def ai_blocked(n: int, nnz: int, d: int, *, t: int, num_blocks: int,
               sizeof_val: int = 8, sizeof_idx: int = 4,
               reuse_factor: float = 0.25) -> TrafficBreakdown:
    """Eq. 4 — CPU blocked (CSB) model.

    ``num_blocks`` is N, the count of nonzero t x t blocks; D = nnz / N.
    B traffic: each block touches z occupied columns => 8*d*N*z bytes, scaled
    by the paper's cache-reuse heuristic (1/4), giving the published ``2dNz``.
    A traffic: within-block indices are short (the paper charges 8 B values +
    effectively no row_ptr term => ``8 nnz``); we keep the published constant
    by charging values only, with indices folded into the reuse-scaled term.
    """
    if num_blocks <= 0:
        raise ValueError("num_blocks must be positive")
    D = nnz / num_blocks
    z = expected_occupied_columns(t, D)
    return TrafficBreakdown(
        flops=flops_spmm(nnz, d),
        bytes_a=sizeof_val * nnz,  # paper's ``8 nnz`` leading term
        bytes_b=reuse_factor * num_blocks * z * d * sizeof_val,
        bytes_c=_traffic_c(n, d, sizeof_val),
        model="blocked",
    )


def ai_blocked_tpu(n: int, nnz: int, d: int, *, t: int, num_blocks: int,
                   sizeof_val: int = 2, sizeof_idx: int = 4) -> TrafficBreakdown:
    """TPU adaptation of Eq. 4 for the BCSR Pallas kernel.

    On TPU the reuse factor is not a heuristic: BlockSpec residency is
    deterministic.  Each nonzero block moves the *whole* t x t A-block (dense
    storage, MXU computes it densely) and the whole t x d B-tile exactly once;
    C accumulates in VMEM and is written once.  There is no 1/4 fudge factor.

    Note FLOPs here are *useful* FLOPs (2*d*nnz); MXU-issued FLOPs are
    2*d*t*t*N.  The ratio nnz/(t*t*N) = D/t^2 is the MXU utilization, reported
    separately by the analyzer.
    """
    return TrafficBreakdown(
        flops=flops_spmm(nnz, d),
        bytes_a=num_blocks * t * t * sizeof_val + num_blocks * sizeof_idx,
        bytes_b=num_blocks * t * d * sizeof_val,
        bytes_c=_traffic_c(n, d, sizeof_val),
        model="blocked_tpu",
    )


def mxu_utilization(nnz: int, t: int, num_blocks: int) -> float:
    """Fraction of MXU-issued FLOPs that are useful in dense-block BCSR."""
    return min(1.0, nnz / (t * t * float(num_blocks)))


def hub_edge_fraction(alpha: float, f: float) -> float:
    """Appendix Eq. 17: nnz_hub / nnz = f^((alpha-2)/(alpha-1)).

    alpha: power-law exponent (2 < alpha < 3 for real networks).
    f: fraction of nodes (by degree) considered hubs.
    """
    if not 0.0 < f <= 1.0:
        raise ValueError("hub fraction f must be in (0, 1]")
    if alpha <= 1.0:
        raise ValueError("alpha must exceed 1")
    expo = (alpha - 2.0) / (alpha - 1.0)
    return f ** expo


def ai_scale_free(n: int, nnz: int, d: int, *, alpha: float = 2.2,
                  hub_fraction: float = 0.001, sizeof_val: int = 8,
                  sizeof_idx: int = 4) -> TrafficBreakdown:
    """Eq. 6 — hub rows of B resident in cache; non-hub accesses random.

    Traffic_B = 8d*(nnz - nnz_hub)    (random part)
              + 8d*n_hub              (hubs loaded once)
    """
    nnz_hub = nnz * hub_edge_fraction(alpha, hub_fraction)
    n_hub = hub_fraction * n
    return TrafficBreakdown(
        flops=flops_spmm(nnz, d),
        bytes_a=_traffic_a_csr(n, nnz, sizeof_val, sizeof_idx),
        bytes_b=(nnz - nnz_hub) * d * sizeof_val + n_hub * d * sizeof_val,
        bytes_c=_traffic_c(n, d, sizeof_val),
        model="scale_free",
    )


def ai_binned(n: int, nnz: int, d: int, *, slab_rows: int,
              slabs_touched: int, num_visits: int, row_tile: int = 8,
              sizeof_val: int = 8, sizeof_idx: int = 4) -> TrafficBreakdown:
    """Binning-traffic model for the two-phase binned kernel (PR 8).

    Propagation blocking trades B gathers for partial-C writes: slab-major
    traversal reads each *touched* B slab exactly once per pass
    (``slabs_touched * slab_rows * d`` instead of Eq. 2's ``nnz * d``),
    and pays for it with one ``[row_tile, d]`` partial written and read
    back per (slab, row-tile) visit before the final C write.  On skewed
    matrices hub columns collapse many nonzeros into few visits, so the
    partial traffic stays small while the B saving is ~``avg_degree``x;
    on uniform matrices ``num_visits`` approaches ``tiles * slabs`` and
    the model correctly prices the kernel out.

    A traffic is the bin layout (values + column + row ids per nonzero,
    plus the slab pointer array).
    """
    partials = 2.0 * num_visits * row_tile * d * sizeof_val
    return TrafficBreakdown(
        flops=flops_spmm(nnz, d),
        bytes_a=nnz * (sizeof_val + 2 * sizeof_idx)
        + (slabs_touched + 1) * sizeof_idx,
        bytes_b=min(slabs_touched * slab_rows, n) * d * sizeof_val,
        bytes_c=_traffic_c(n, d, sizeof_val) + partials,
        model="binned",
    )


def ai_rowsplit(n: int, nnz: int, d: int, *, window: int, chunk: int = 128,
                bytes_b: float | None = None, sizeof_val: int = 8,
                sizeof_idx: int = 4) -> TrafficBreakdown:
    """Merge-path row-split model: equal-nnz chunks, windowed partials.

    B traffic follows the structure regime (the gathers are the same as
    CSR's; pass the regime's ``bytes_b``, defaulting to Eq. 2's
    no-reuse term).  The load-balance price is the per-chunk
    ``[window, d]`` partial written and read back by the scatter
    epilogue — small when chunks span few rows (skewed matrices), up to
    one extra C-sized pass per ``chunk/window`` on degree-1 rows.
    """
    num_chunks = max(1, -(-nnz // chunk))
    partials = 2.0 * num_chunks * window * d * sizeof_val
    return TrafficBreakdown(
        flops=flops_spmm(nnz, d),
        bytes_a=nnz * (sizeof_val + 2 * sizeof_idx),
        bytes_b=nnz * d * sizeof_val if bytes_b is None else bytes_b,
        bytes_c=_traffic_c(n, d, sizeof_val) + partials,
        model="rowsplit",
    )


def ai_ell_coo(n: int, nnz: int, d: int, *, k_cut: int, tail_nnz: int,
               bytes_b: float | None = None, sizeof_val: int = 8,
               sizeof_idx: int = 4) -> TrafficBreakdown:
    """Tail-fraction model for the hybrid sorted-ELL + COO layout.

    A traffic splits into the padded body (value + column per slot,
    ``n * k_cut`` slots) and the COO tail (value + row + column per
    overflow entry).  B gathers follow the issued slots — body padding
    gathers rows it multiplies by zero — so the default B term charges
    ``(n * k_cut + tail_nnz)`` gathers; regime-aware callers scale their
    structure model by the same issued/nnz ratio and pass ``bytes_b``.
    """
    issued = n * k_cut + tail_nnz
    return TrafficBreakdown(
        flops=flops_spmm(nnz, d),
        bytes_a=n * k_cut * (sizeof_val + sizeof_idx)
        + tail_nnz * (sizeof_val + 2 * sizeof_idx),
        bytes_b=issued * d * sizeof_val if bytes_b is None else bytes_b,
        bytes_c=_traffic_c(n, d, sizeof_val),
        model="ell_coo",
    )


def shard_traffic(tb: TrafficBreakdown, *, nnz_fraction: float,
                  rows_fraction: float,
                  bytes_b: float | None = None) -> TrafficBreakdown:
    """Scale a whole-matrix traffic model down to one shard.

    The sharded tier (``repro.sparse.shard``) evaluates a per-shard AI:
    FLOPs and A-traffic scale with the shard's share of the nonzeros, the
    C write-out with its share of the output rows, and the B term either
    scales with nnz too (random/scale-free gathers follow the nonzeros)
    or is replaced outright (``bytes_b``) when the shard streams B
    wholesale, as a diagonal band does.

    Args:
        tb: the whole-matrix :class:`TrafficBreakdown` from the detected
            regime's Section III model.
        nnz_fraction: this shard's nnz / total nnz.
        rows_fraction: this shard's output rows / n.
        bytes_b: explicit per-shard B traffic in bytes; ``None`` scales
            ``tb.bytes_b`` by ``nnz_fraction``.

    Returns:
        A per-shard :class:`TrafficBreakdown` (model name suffixed with
        ``"+shard"``).
    """
    return TrafficBreakdown(
        flops=tb.flops * nnz_fraction,
        bytes_a=tb.bytes_a * nnz_fraction,
        bytes_b=tb.bytes_b * nnz_fraction if bytes_b is None else bytes_b,
        bytes_c=tb.bytes_c * rows_fraction,
        model=f"{tb.model}+shard",
    )


_MODELS = {
    "random": ai_random,
    "diagonal": ai_diagonal,
    "blocked": ai_blocked,
    "blocked_tpu": ai_blocked_tpu,
    "scale_free": ai_scale_free,
}


def arithmetic_intensity(model: str, n: int, nnz: int, d: int,
                         **kwargs) -> TrafficBreakdown:
    """Dispatch to one of the paper's models by name."""
    try:
        fn = _MODELS[model]
    except KeyError:
        raise ValueError(f"unknown sparsity model {model!r}; "
                         f"choose from {sorted(_MODELS)}") from None
    return fn(n, nnz, d, **kwargs)
