"""Extract roofline inputs from a lowered/compiled XLA artifact.

``compiled.cost_analysis()`` supplies HLO FLOPs and bytes accessed, but says
nothing about collectives.  We recover collective traffic by parsing the HLO
text: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` instruction contributes its operand
bytes (the data each device injects into the interconnect).

The parser is two-pass: pass 1 records every instruction's *result* shape;
pass 2 resolves collective operands (which may be printed with or without
inline shapes) against that table.  Async pairs (``-start``/``-done``) are
counted once, on the ``-start``.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e3m4": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1,
}

# dtype[d0,d1,...]{layout}  — layout part optional, dims may be empty (scalar)
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%name = <shape(s)> opcode(`  — opcode may carry -start suffix.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")


def _shape_bytes(text: str) -> int:
    """Total bytes of every shape token appearing in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        count = 1
        if dims:
            for d in dims.split(","):
                if d:
                    count *= int(d)
        total += count * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective in an HLO module dump.

    Returns a dict with one entry per collective kind plus ``total``.
    Values are bytes *per partition/module* (the module is the per-device
    SPMD program); multiply by device count for fleet-global traffic.
    """
    result_bytes: Dict[str, int] = {}
    pending = []  # (opcode, operand_names, inline_operand_bytes)

    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_part, opcode = m.group(1), m.group(2), m.group(3)
        result_bytes[name] = _shape_bytes(result_part)

        base = opcode
        if base.endswith("-start"):
            base = base[: -len("-start")]
        if base.endswith("-done"):
            continue  # counted at -start
        if base not in _COLLECTIVE_OPS:
            continue
        # Operand section: between the first '(' after opcode and its match.
        idx = line.find(opcode + "(")
        operand_section = line[idx + len(opcode) + 1:]
        depth = 1
        out = []
        for ch in operand_section:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            out.append(ch)
        operand_section = "".join(out)
        inline = _shape_bytes(operand_section)
        operand_names = re.findall(r"%([\w.\-]+)", operand_section)
        pending.append((base, operand_names, inline))

    totals: Dict[str, float] = defaultdict(float)
    for base, operand_names, inline in pending:
        if inline > 0:
            nbytes = inline
        else:
            nbytes = sum(result_bytes.get(n, 0) for n in operand_names)
        totals[base] += float(nbytes)
    totals["total"] = float(sum(v for k, v in totals.items() if k != "total"))
    return dict(totals)


def count_collectives(hlo_text: str) -> Dict[str, int]:
    """Number of collective instructions per kind (for redundancy hunting)."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        opcode = m.group(3)
        base = opcode[:-len("-start")] if opcode.endswith("-start") else opcode
        if base in _COLLECTIVE_OPS and not opcode.endswith("-done"):
            counts[base] += 1
    return dict(counts)


def cost_summary(compiled) -> Dict[str, float]:
    """Flatten ``compiled.cost_analysis()`` to the fields we report.

    XLA returns per-partition module costs: ``flops`` and ``bytes accessed``
    describe ONE device's program.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    transcendentals = float(cost.get("transcendentals", 0.0))
    return {"flops_per_device": flops, "bytes_per_device": byts,
            "transcendentals_per_device": transcendentals}


def memory_summary(compiled) -> Dict[str, float]:
    """Per-device memory footprint from ``compiled.memory_analysis()``."""
    mem = compiled.memory_analysis()
    out = {}
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes"):
        out[key] = float(getattr(mem, key, 0.0))
    out["total_hbm_bytes"] = (
        out["argument_size_in_bytes"] + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"] - out.get("alias_size_in_bytes", 0.0))
    return out
