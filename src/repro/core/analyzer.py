"""Tie the dry-run artifacts to the sparsity-aware roofline report.

Input records are produced by ``repro.launch.dryrun`` (one JSON dict per
(arch x shape x mesh) cell) and contain per-device HLO cost, memory and
collective-byte figures plus the model-level useful-FLOP estimate.

This module converts each record into the three-term distributed roofline
(``repro.core.roofline.DistributedRoofline``), attaches the paper's
sparsity-aware corrections for sparse model components (MoE dispatch =
blocked regime, sliding-window attention = diagonal/banded regime), and
renders the EXPERIMENTS.md tables.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

from repro.core.hardware import TPU_V5E, HardwareSpec
from repro.core.roofline import DistributedRoofline
from repro.core import sparsity_models as sm


def analyze_record(record: Dict, hw: HardwareSpec = TPU_V5E) -> Dict:
    """Merge a dry-run record with derived roofline terms."""
    chips = int(record["chips"])
    flops_dev = float(record["cost"]["flops_per_device"])
    bytes_dev = float(record["cost"]["bytes_per_device"])
    coll_dev = float(record.get("collectives", {}).get("total", 0.0))
    model_flops = float(record.get("model_flops", 0.0))

    roof = DistributedRoofline(
        name=f"{record['arch']}/{record['shape']}/{record['mesh']}",
        chips=chips,
        hlo_flops=flops_dev * chips,
        hlo_bytes=bytes_dev * chips,
        collective_bytes=coll_dev * chips,
        hardware=hw,
        model_flops=model_flops,
    )
    out = dict(record)
    out["roofline"] = roof.as_dict()
    out["roofline"]["hint"] = bottleneck_hint(roof, record)
    sparse = record.get("sparse_components")
    if sparse:
        out["sparsity_corrections"] = [
            sparse_component_ai(c) for c in sparse]
    return out


def sparse_component_ai(component: Dict) -> Dict:
    """Apply the paper's AI model to one sparse model component.

    Components are emitted by the model zoo:
      MoE expert FFN  -> blocked_tpu regime (block-diagonal BCSR SpMM)
      sliding-window  -> diagonal regime (banded attention map)
      full attention  -> random regime upper-bounds an unstructured map
    """
    regime = component["regime"]
    kwargs = {k: component[k] for k in ("t", "num_blocks", "alpha",
                                        "hub_fraction") if k in component}
    tb = sm.arithmetic_intensity(
        regime, component["n"], component["nnz"], component["d"],
        sizeof_val=component.get("sizeof_val", 2), **kwargs)
    out = {
        "name": component["name"],
        "regime": regime,
        "ai": tb.ai,
        "flops": tb.flops,
        "bytes": tb.total_bytes,
        "attainable_flops_per_s": TPU_V5E.attainable(tb.ai),
    }
    if regime == "blocked_tpu":
        out["mxu_utilization"] = sm.mxu_utilization(
            component["nnz"], component["t"], component["num_blocks"])
    return out


def bottleneck_hint(roof: DistributedRoofline, record: Dict) -> str:
    """One sentence on what would move the dominant term down."""
    dom = roof.dominant
    if dom == "compute":
        ratio = roof.useful_compute_ratio
        if ratio < 0.5:
            return ("compute-bound with useful ratio "
                    f"{ratio:.2f}: cut remat recompute / fuse gather-einsums "
                    "before touching sharding")
        return ("compute-bound near useful peak: only faster kernels "
                "(MXU-aligned BCSR tiles, fused attention) help")
    if dom == "memory":
        return ("memory-bound: raise AI — larger per-device batch/tiles, "
                "bf16 weights/activations, KV-cache quantization, or the "
                "paper's blocked layout to cut B traffic")
    return ("collective-bound: reshard to cut all-gather volume (FSDP->TP "
            "boundary), overlap via async collectives, or compress "
            "cross-pod gradients (int8)")


def format_roofline_table(records: Iterable[Dict]) -> str:
    """Markdown table for EXPERIMENTS.md Section Roofline."""
    rows: List[str] = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | MODEL/HLO | MFU ceiling |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        r = rec["roofline"]
        rows.append(
            "| {arch} | {shape} | {mesh} | {c:.3e} | {m:.3e} | {k:.3e} | "
            "{dom} | {ratio:.2f} | {mfu:.2%} |".format(
                arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                c=r["compute_s"], m=r["memory_s"], k=r["collective_s"],
                dom=r["dominant"], ratio=r["useful_compute_ratio"],
                mfu=r["mfu_upper_bound"]))
    return "\n".join(rows)
