"""Model assembly: init / forward / decode for all 10 architectures.

Layer stacks are ``jax.lax.scan``s over *pattern groups* (one group = one
repetition of ``cfg.layer_pattern``), with per-group parameters stacked on a
leading axis.  HLO size and compile time are therefore O(period), not
O(num_layers) — required to compile qwen2-72b (80L) and qwen3-moe (94L) on
this container.

Families:
  dense / vlm      decoder-only transformer (global or local/global pattern)
  moe              dense attention + MoE FFN (repro.models.moe)
  ssm              mamba-1 stack (repro.models.ssm)
  hybrid           RG-LRU + local attention (repro.models.rglru)
  encdec           whisper: encoder stack + decoder w/ cross-attention;
                   the audio conv frontend is a stub (precomputed frames)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.sharding_ctx import NO_SHARDING

COMPUTE_DTYPE = jnp.bfloat16

#: Fuse QKV (and MLP gate/up) projections into single matmuls.  Same math,
#: one backward dx all-reduce instead of three/two per layer — the measured
#: per-layer activation-gradient collectives dominate the TP collective term
#: (EXPERIMENTS.md Section Perf, hypothesis P4).  Module-level switch so the
#: baseline (unfused) configuration stays reproducible.
FUSE_PROJECTIONS = False


def set_fused_projections(flag: bool) -> None:
    global FUSE_PROJECTIONS
    FUSE_PROJECTIONS = flag


def _norm_fn(cfg):
    if cfg.family == "encdec":
        return L.init_layernorm, functools.partial(L.layernorm)
    return L.init_rmsnorm, functools.partial(L.rmsnorm, eps=cfg.norm_eps)


def _scale_embed(cfg) -> bool:
    # Gemma-family models scale embeddings by sqrt(d_model); within the
    # assigned pool that is exactly the geglu archs.
    return cfg.mlp_variant == "geglu"


# ---------------------------------------------------------------------------
# Per-layer init.
# ---------------------------------------------------------------------------

def _init_attn(key, cfg) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    if FUSE_PROJECTIONS:
        fused = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
        return {
            "wqkv": L.init_dense(k1, d, fused, bias=cfg.qkv_bias),
            "wo": L.init_dense(k4, cfg.num_heads * cfg.head_dim, d),
        }
    return {
        "wq": L.init_dense(k1, d, cfg.num_heads * cfg.head_dim,
                           bias=cfg.qkv_bias),
        "wk": L.init_dense(k2, d, cfg.num_kv_heads * cfg.head_dim,
                           bias=cfg.qkv_bias),
        "wv": L.init_dense(k3, d, cfg.num_kv_heads * cfg.head_dim,
                           bias=cfg.qkv_bias),
        "wo": L.init_dense(k4, cfg.num_heads * cfg.head_dim, d),
    }


def _init_layer(key, cfg, kind: str) -> Dict:
    init_norm, _ = _norm_fn(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    if kind == "ssm":
        return {"ln": init_norm(d),
                "mamba": S.init_mamba(keys[0], d, cfg.ssm_state,
                                      cfg.ssm_conv, cfg.ssm_expand)}
    if kind == "rglru":
        return {"ln1": init_norm(d),
                "rglru": R.init_rglru(keys[0], d, cfg.rnn_width or d,
                                      cfg.ssm_conv),
                "ln2": init_norm(d),
                "mlp": L.init_mlp(keys[1], d, cfg.d_ff, cfg.mlp_variant,
                                  fused=FUSE_PROJECTIONS)}
    layer = {"ln1": init_norm(d), "attn": _init_attn(keys[0], cfg),
             "ln2": init_norm(d)}
    if cfg.num_experts:
        layer["moe"] = MOE.init_moe(keys[1], d, cfg.moe_d_ff,
                                    cfg.num_experts)
    else:
        layer["mlp"] = L.init_mlp(keys[1], d, cfg.d_ff, cfg.mlp_variant,
                                  fused=FUSE_PROJECTIONS)
    if cfg.family == "encdec":
        layer["ln_cross"] = init_norm(d)
        layer["cross"] = _init_attn(keys[2], cfg)
    return layer


def init_params(cfg: ModelConfig, key) -> Dict:
    """Full parameter pytree; repeated groups stacked on a leading axis."""
    period = cfg.layer_pattern
    groups = cfg.num_layers // len(period)
    k_embed, k_layers, k_head, k_enc, k_mm = jax.random.split(key, 5)

    layers = {}
    for i, kind in enumerate(period):
        keys = jax.random.split(jax.random.fold_in(k_layers, i), groups)
        layers[f"p{i}"] = jax.vmap(
            lambda kk: _init_layer(kk, cfg, kind))(keys)

    init_norm, _ = _norm_fn(cfg)
    params = {
        "embed": L.init_embedding(k_embed, cfg.padded_vocab, cfg.d_model),
        "layers": layers,
        "final_norm": init_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(k_head, cfg.d_model,
                                         cfg.padded_vocab)
    if cfg.family == "encdec":
        keys = jax.random.split(k_enc, cfg.encoder_layers)
        enc_cfg_kind = "global"
        params["encoder"] = {
            "layers": jax.vmap(
                lambda kk: {
                    "ln1": init_norm(cfg.d_model),
                    "attn": _init_attn(jax.random.fold_in(kk, 0), cfg),
                    "ln2": init_norm(cfg.d_model),
                    "mlp": L.init_mlp(jax.random.fold_in(kk, 1),
                                      cfg.d_model, cfg.d_ff,
                                      cfg.mlp_variant),
                })(keys),
            "norm": init_norm(cfg.d_model),
        }
        del enc_cfg_kind
    if cfg.family == "vlm":
        params["mm_proj"] = L.init_dense(k_mm, cfg.d_model, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Attention application (train/prefill and decode).
# ---------------------------------------------------------------------------

def _qkv(p, cfg, x, positions, ctx, rope: bool = True):
    b, s, _ = x.shape
    if "wqkv" in p:
        nq = cfg.num_heads * cfg.head_dim
        nkv = cfg.num_kv_heads * cfg.head_dim
        fused = L.dense(p["wqkv"], x)
        q = fused[..., :nq].reshape(b, s, cfg.num_heads, cfg.head_dim)
        k = fused[..., nq:nq + nkv].reshape(b, s, cfg.num_kv_heads,
                                            cfg.head_dim)
        v = fused[..., nq + nkv:].reshape(b, s, cfg.num_kv_heads,
                                          cfg.head_dim)
    else:
        q = L.dense(p["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
        k = L.dense(p["wk"], x).reshape(b, s, cfg.num_kv_heads,
                                        cfg.head_dim)
        v = L.dense(p["wv"], x).reshape(b, s, cfg.num_kv_heads,
                                        cfg.head_dim)
    if rope and cfg.family != "encdec":
        if cfg.mrope and positions.ndim == 3:
            q = L.apply_mrope(q, positions, cfg.rope_theta)
            k = L.apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
    q = ctx.constrain(q, "heads_bshd")
    k = ctx.constrain(k, "kv_bskd")
    v = ctx.constrain(v, "kv_bskd")
    return q, k, v


def _attn_train(p, cfg, x, positions, kind, ctx):
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions, ctx)
    if kind == "local" and s > cfg.window_size:
        out = A.local_attention(q, k, v, window=cfg.window_size)
    else:
        out = A.chunked_attention(q, k, v, causal=True)
    out = ctx.constrain(out, "heads_bshd")
    return L.dense(p["wo"], out.reshape(b, s, -1))


def _cross_train(p, cfg, x, enc_out, ctx):
    b, s, _ = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    se = enc_out.shape[1]
    k = L.dense(p["wk"], enc_out).reshape(b, se, cfg.num_kv_heads,
                                          cfg.head_dim)
    v = L.dense(p["wv"], enc_out).reshape(b, se, cfg.num_kv_heads,
                                          cfg.head_dim)
    out = A.chunked_attention(q, k, v, causal=False)
    return L.dense(p["wo"], out.reshape(b, s, -1))


def _attn_decode(p, cfg, x, cache, pos, positions, kind, ctx):
    """x: [B,1,d]; cache: {"k","v"} [B,S_c,Hkv,D]; pos: scalar int32."""
    b = x.shape[0]
    q, k_new, v_new = _qkv(p, cfg, x, positions, ctx)
    s_c = cache["k"].shape[1]
    if kind == "local":
        slot = jnp.mod(pos, s_c)
        window_full = pos >= s_c
        slots = jnp.arange(s_c)
        mask = jnp.where(window_full, True, slots <= pos)[None, :]
        mask = jnp.broadcast_to(mask, (b, s_c))
    else:
        slot = jnp.minimum(pos, s_c - 1)
        slots = jnp.arange(s_c)
        mask = jnp.broadcast_to((slots <= pos)[None, :], (b, s_c))
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    k_cache = ctx.constrain(k_cache, "kv_cache")
    v_cache = ctx.constrain(v_cache, "kv_cache")
    out = A.decode_attention(q, k_cache, v_cache, mask)
    out = L.dense(p["wo"], out.reshape(b, 1, -1))
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Layer application.
# ---------------------------------------------------------------------------

def _apply_ffn(layer, cfg, x, ctx):
    _, norm = _norm_fn(cfg)
    h = norm(layer["ln2"], x)
    if cfg.num_experts:
        return x + MOE.moe_ffn(layer["moe"], h, k=cfg.num_experts_per_token,
                               num_experts=cfg.num_experts,
                               capacity_factor=cfg.moe_capacity_factor,
                               ctx=ctx)
    return x + L.mlp(layer["mlp"], h, cfg.mlp_variant, ctx=ctx)


def _apply_layer_train(layer, cfg, kind, x, positions, ctx, enc_out=None):
    _, norm = _norm_fn(cfg)
    if kind == "ssm":
        return x + S.mamba_forward(layer["mamba"], norm(layer["ln"], x),
                                   ctx=ctx)
    if kind == "rglru":
        x = x + R.rglru_forward(layer["rglru"], norm(layer["ln1"], x),
                                ctx=ctx)
        return x + L.mlp(layer["mlp"], norm(layer["ln2"], x),
                         cfg.mlp_variant, ctx=ctx)
    x = x + _attn_train(layer["attn"], cfg, norm(layer["ln1"], x),
                        positions, kind, ctx)
    if cfg.family == "encdec":
        x = x + _cross_train(layer["cross"], cfg,
                             norm(layer["ln_cross"], x), enc_out, ctx)
    return _apply_ffn(layer, cfg, x, ctx)


def _apply_layer_decode(layer, cfg, kind, x, cache, pos, positions, ctx,
                        enc_out=None):
    _, norm = _norm_fn(cfg)
    if kind == "ssm":
        out, new_cache = S.mamba_decode(layer["mamba"],
                                        cache, norm(layer["ln"], x))
        return x + out, new_cache
    if kind == "rglru":
        out, new_rnn = R.rglru_decode(layer["rglru"], cache["rnn"],
                                      norm(layer["ln1"], x))
        x = x + out
        x = x + L.mlp(layer["mlp"], norm(layer["ln2"], x), cfg.mlp_variant)
        return x, {"rnn": new_rnn}
    out, new_kv = _attn_decode(layer["attn"], cfg, norm(layer["ln1"], x),
                               cache["kv"], pos, positions, kind, ctx)
    x = x + out
    new_cache = {"kv": new_kv}
    if cfg.family == "encdec":
        q = L.dense(layer["cross"]["wq"],
                    norm(layer["ln_cross"], x)).reshape(
            x.shape[0], 1, cfg.num_heads, cfg.head_dim)
        sc = cache["cross_k"].shape[1]
        mask = jnp.ones((x.shape[0], sc), bool)
        cr = A.decode_attention(q, cache["cross_k"], cache["cross_v"], mask)
        x = x + L.dense(layer["cross"]["wo"],
                        cr.reshape(x.shape[0], 1, -1))
        new_cache["cross_k"] = cache["cross_k"]
        new_cache["cross_v"] = cache["cross_v"]
    x = _apply_ffn(layer, cfg, x, ctx)
    return x, new_cache


# ---------------------------------------------------------------------------
# Encoder (whisper) and embedding front.
# ---------------------------------------------------------------------------

def _run_encoder(cfg, params, frames, ctx, remat: bool = True):
    """frames: [B, S_enc, d] precomputed stub embeddings."""
    _, norm = _norm_fn(cfg)
    x = frames.astype(COMPUTE_DTYPE)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(
        x.dtype)

    def body(h, lp):
        b, s, _ = h.shape
        a = norm(lp["ln1"], h)
        q = L.dense(lp["attn"]["wq"], a).reshape(b, s, cfg.num_heads,
                                                 cfg.head_dim)
        k = L.dense(lp["attn"]["wk"], a).reshape(b, s, cfg.num_kv_heads,
                                                 cfg.head_dim)
        v = L.dense(lp["attn"]["wv"], a).reshape(b, s, cfg.num_kv_heads,
                                                 cfg.head_dim)
        o = A.chunked_attention(q, k, v, causal=False)
        h = h + L.dense(lp["attn"]["wo"], o.reshape(b, s, -1))
        h = h + L.mlp(lp["mlp"], norm(lp["ln2"], h), cfg.mlp_variant,
                      ctx=ctx)
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body) if remat else body, x,
                        params["encoder"]["layers"])
    return norm(params["encoder"]["norm"], x)


def _embed_tokens(cfg, params, batch, ctx, add_encdec_pos: bool = True):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens, scale=_scale_embed(cfg),
                dtype=COMPUTE_DTYPE)
    if cfg.family == "vlm" and "mm_embeds" in batch:
        mm = L.dense(params["mm_proj"], batch["mm_embeds"].astype(x.dtype))
        n_mm = mm.shape[1]
        x = jnp.concatenate([mm, x[:, n_mm:]], axis=1)
    if cfg.family == "encdec" and add_encdec_pos:
        pos_table = L.sinusoidal_positions(tokens.shape[1], cfg.d_model)
        x = x + pos_table[None].astype(x.dtype)
    return ctx.constrain(x, "tokens_bse")


def _positions_for(cfg, batch):
    tokens = batch["tokens"]
    if cfg.mrope and "positions_3d" in batch:
        return batch["positions_3d"]
    b, s = tokens.shape[0], tokens.shape[1]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


# ---------------------------------------------------------------------------
# Public API: forward (train/prefill), init_cache, decode_step.
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Dict, batch: Dict, *,
            ctx=NO_SHARDING, remat: bool = True,
            return_pre_logits: bool = False) -> jnp.ndarray:
    """Returns logits [B, S, V] (fp32), or the final-norm hidden states
    [B, S, E] when ``return_pre_logits`` (chunked-loss path)."""
    x = _embed_tokens(cfg, params, batch, ctx)
    positions = _positions_for(cfg, batch)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _run_encoder(cfg, params, batch["frames"], ctx, remat)

    period = cfg.layer_pattern

    def one_layer(kind):
        def apply(h, lp, pos):
            h = _apply_layer_train(lp, cfg, kind, h, pos, ctx, enc_out)
            return ctx.constrain(h, "tokens_bse")
        return apply

    def group_body(h, gparams):
        # Per-LAYER remat (not per-group): backward rematerializes one
        # layer at a time, so peak residency is O(1) in the pattern
        # period — recurrentgemma's 19-layer period held 19 layers of
        # intermediates live under group-level remat (EXPERIMENTS.md
        # Section Perf, P8).  Saved carries are the SP-sharded residual
        # stream only.
        for i, kind in enumerate(period):
            fn = one_layer(kind)
            if remat:
                fn = jax.checkpoint(fn)
            h = fn(h, gparams[f"p{i}"], positions)
        return h, None

    x, _ = jax.lax.scan(group_body, x, params["layers"])
    _, norm = _norm_fn(cfg)
    x = norm(params["final_norm"], x)
    if return_pre_logits:
        return x
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.dense(params["lm_head"], x)
    logits = ctx.constrain(logits, "logits_bsv")
    return logits.astype(jnp.float32)


def unembed_table(cfg: ModelConfig, params: Dict) -> jnp.ndarray:
    """[V_padded, E] output-projection table (tied or separate)."""
    if cfg.tie_embeddings:
        return params["embed"]["table"]
    return params["lm_head"]["kernel"].T


def _layer_cache(cfg, kind, batch: int, cache_len: int):
    if kind == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in),
                                  COMPUTE_DTYPE),
                "h": jnp.zeros((batch, d_in, cfg.ssm_state), jnp.float32)}
    if kind == "rglru":
        rw = cfg.rnn_width or cfg.d_model
        return {"rnn": {
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, rw), COMPUTE_DTYPE),
            "h": jnp.zeros((batch, rw), jnp.float32)}}
    s = min(cache_len, cfg.window_size) if kind == "local" else cache_len
    kv = {"k": jnp.zeros((batch, s, cfg.num_kv_heads, cfg.head_dim),
                         COMPUTE_DTYPE),
          "v": jnp.zeros((batch, s, cfg.num_kv_heads, cfg.head_dim),
                         COMPUTE_DTYPE)}
    cache = {"kv": kv}
    if cfg.family == "encdec":
        cache["cross_k"] = jnp.zeros(
            (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim),
            COMPUTE_DTYPE)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    """Decode cache pytree mirroring params["layers"] group stacking."""
    period = cfg.layer_pattern
    groups = cfg.num_layers // len(period)
    cache = {}
    for i, kind in enumerate(period):
        one = _layer_cache(cfg, kind, batch, cache_len)
        cache[f"p{i}"] = jax.tree.map(
            lambda a: jnp.zeros((groups,) + a.shape, a.dtype), one)
    return cache


def prime_cross_cache(cfg: ModelConfig, params: Dict, cache: Dict,
                      enc_out: jnp.ndarray) -> Dict:
    """Fill the (constant) cross-attention K/V of an enc-dec decode cache."""
    b, se, _ = enc_out.shape

    def per_group(gparams):
        lp = gparams["p0"]["cross"]
        k = L.dense(lp["wk"], enc_out).reshape(b, se, cfg.num_kv_heads,
                                               cfg.head_dim)
        v = L.dense(lp["wv"], enc_out).reshape(b, se, cfg.num_kv_heads,
                                               cfg.head_dim)
        return k.astype(COMPUTE_DTYPE), v.astype(COMPUTE_DTYPE)

    ks, vs = jax.vmap(per_group)(params["layers"])
    new = dict(cache)
    p0 = dict(cache["p0"])
    p0["cross_k"], p0["cross_v"] = ks, vs
    new["p0"] = p0
    return new


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jnp.ndarray, pos: jnp.ndarray, *,
                ctx=NO_SHARDING,
                batch_extras: Optional[Dict] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.

    tokens: [B] int32 current tokens; pos: scalar int32 write position
    (uniform across the batch — continuous batching with per-sequence
    positions is an orthogonal serving feature).
    Returns (logits [B, V], new cache).
    """
    b = tokens.shape[0]
    batch = {"tokens": tokens[:, None]}
    if batch_extras:
        batch.update(batch_extras)
    x = _embed_tokens(cfg, params, batch, ctx, add_encdec_pos=False)
    if cfg.family == "encdec":
        # Gather the sinusoidal position row for the current step.
        table = L.sinusoidal_positions(65536, cfg.d_model)
        x = x + jax.lax.dynamic_index_in_dim(
            table, pos, keepdims=True)[None].astype(x.dtype)
    if cfg.mrope and batch_extras and "positions_3d" in batch_extras:
        positions = batch_extras["positions_3d"]
    else:
        positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(
            jnp.int32)

    period = cfg.layer_pattern

    def group_body(h, xs):
        gparams, gcache = xs
        new_gcache = {}
        for i, kind in enumerate(period):
            h, new_gcache[f"p{i}"] = _apply_layer_decode(
                gparams[f"p{i}"], cfg, kind, h, gcache[f"p{i}"], pos,
                positions, ctx)
            h = ctx.constrain(h, "tokens_bse")
        return h, new_gcache

    x, new_cache = jax.lax.scan(group_body, x, (params["layers"], cache))
    _, norm = _norm_fn(cfg)
    x = norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.dense(params["lm_head"], x)
    return logits[:, 0].astype(jnp.float32), new_cache
