"""Model zoo: 10 assigned architectures over shared functional blocks."""
from repro.models.model import (
    decode_step, forward, init_cache, init_params, prime_cross_cache,
)
from repro.models.sharding_ctx import NO_SHARDING, ShardingCtx

__all__ = ["decode_step", "forward", "init_cache", "init_params",
           "prime_cross_cache", "NO_SHARDING", "ShardingCtx"]
