"""Mamba-1 selective SSM block (falcon-mamba-7b).

The recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t is the extreme of
the paper's diagonal-sparsity regime: the "matrix" coupling timesteps is
bidiagonal, so state traffic is constant per token (DESIGN.md Section 6).

Training/prefill uses a chunked associative scan: lax.scan over chunks of
``chunk`` timesteps with the [B, chunk, d_in, N] discretized tensors
materialized per chunk only (the real Mamba kernel fuses this in SRAM; the
chunking bounds HBM the same way), and a log-depth associative scan inside
each chunk.  Decode is the O(1) recurrence update.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_mamba(key, d: int, state: int, conv: int, expand: int) -> Dict:
    d_in = expand * d
    dt_rank = max(d // 16, 1)
    keys = jax.random.split(key, 6)
    return {
        "in_proj": L.init_dense(keys[0], d, 2 * d_in),
        "conv_w": L.he_init(keys[1], (conv, d_in), fan_in=conv),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": L.init_dense(keys[2], d_in, dt_rank + 2 * state),
        "dt_proj": L.init_dense(keys[3], dt_rank, d_in, bias=True),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, state + 1, dtype=jnp.float32)[None, :], (d_in, 1))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": L.init_dense(keys[4], d_in, d),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv1d, kernel size K (unrolled — K is 4).

    u: [B,S,C]; w: [K,C]; state: [B,K-1,C] left-context or None (zeros).
    """
    K = w.shape[0]
    if state is None:
        up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(up[:, i:i + u.shape[1], :] * w[i].astype(u.dtype)
              for i in range(K))
    return out + b.astype(u.dtype)


def _ssm_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def _discretize(params, u):
    """u: [..., d_in] -> (dA, dBu, C) with state dim appended."""
    dt_rank = params["dt_proj"]["kernel"].shape[0]
    state = params["A_log"].shape[1]
    xdbc = L.dense(params["x_proj"], u)
    dt_r = xdbc[..., :dt_rank]
    Bc = xdbc[..., dt_rank:dt_rank + state].astype(jnp.float32)
    Cc = xdbc[..., dt_rank + state:].astype(jnp.float32)
    dt = jax.nn.softplus(L.dense(params["dt_proj"], dt_r)
                         .astype(jnp.float32))           # [..., d_in]
    A = -jnp.exp(params["A_log"])                         # [d_in, N]
    dA = jnp.exp(dt[..., None] * A)                       # [..., d_in, N]
    dBu = (dt * u.astype(jnp.float32))[..., None] * Bc[..., None, :]
    return dA, dBu, Cc


def mamba_forward(params: Dict, x: jnp.ndarray, *, chunk: int = 256,
                  ctx=None) -> jnp.ndarray:
    """x: [B,S,d] -> [B,S,d].  S must be divisible by ``chunk``."""
    B, S, d = x.shape
    ch = min(chunk, S)
    assert S % ch == 0
    uz = L.dense(params["in_proj"], x)
    u, z = jnp.split(uz, 2, axis=-1)
    u = jax.nn.silu(_causal_conv(u, params["conv_w"], params["conv_b"]))
    if ctx is not None:
        u = ctx.constrain(u, "ssm_bsdn")
    d_in = u.shape[-1]
    state = params["A_log"].shape[1]

    u_chunks = jnp.moveaxis(u.reshape(B, S // ch, ch, d_in), 1, 0)

    def chunk_step(h, u_c):
        dA, dBu, Cc = _discretize(params, u_c)            # [B,ch,d_in,N]
        dBu = dBu.at[:, 0].add(dA[:, 0] * h)              # fold carry in
        _, hs = jax.lax.associative_scan(_ssm_combine, (dA, dBu), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cc)
        return hs[:, -1], (y.astype(x.dtype), u_c)

    h0 = jnp.zeros((B, d_in, state), jnp.float32)
    _, (y_chunks, u_back) = jax.lax.scan(chunk_step, h0, u_chunks)
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(B, S, d_in)
    y = y + u * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return L.dense(params["out_proj"], y)


def init_mamba_cache(params: Dict, batch: int) -> Dict:
    conv, d_in = params["conv_w"].shape
    state = params["A_log"].shape[1]
    return {
        "conv": jnp.zeros((batch, conv - 1, d_in), jnp.bfloat16),
        "h": jnp.zeros((batch, d_in, state), jnp.float32),
    }


def mamba_decode(params: Dict, cache: Dict,
                 x: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """x: [B,1,d] -> ([B,1,d], cache')."""
    uz = L.dense(params["in_proj"], x)
    u, z = jnp.split(uz, 2, axis=-1)                      # [B,1,d_in]
    conv_in = cache["conv"]
    u_conv = _causal_conv(u, params["conv_w"], params["conv_b"],
                          state=conv_in)
    u_act = jax.nn.silu(u_conv)                           # [B,1,d_in]
    new_conv = jnp.concatenate(
        [conv_in[:, 1:], u.astype(conv_in.dtype)], axis=1)
    dA, dBu, Cc = _discretize(params, u_act[:, 0])        # [B,d_in,N]
    h = dA * cache["h"] + dBu
    y = jnp.einsum("bdn,bn->bd", h, Cc)[:, None, :].astype(x.dtype)
    y = y + u_act * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = L.dense(params["out_proj"], y)
    return out, {"conv": new_conv, "h": h}
