"""Shared building blocks: norms, MLPs, rotary embeddings, initializers.

Everything is functional: params are nested dicts of jnp arrays, created by
``init_*`` functions and consumed by pure ``apply``-style functions.  Compute
runs in bf16 (TPU-native) with fp32 master params and fp32 normalization /
softmax internals.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16


def he_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, dtype) * std


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_core(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return ((1.0 + scale) * normed).astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    out = ((1.0 + scale) * (xf * inv)).astype(x.dtype)
    return out, (x, scale, inv)


def _rmsnorm_bwd(eps, res, dy):
    """Closed-form backward keeping the residual-stream cotangent in the
    compute dtype (bf16): only the per-token reductions run in fp32, so
    cross-shard collectives of dx move half the bytes (EXPERIMENTS.md
    Section Perf, hypothesis P2)."""
    x, scale, inv = res
    d = x.shape[-1]
    dyf = dy.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    g = (1.0 + scale)
    # dx = g*inv*dy - x * inv^3/d * sum(g*dy*x)
    s = jnp.sum(dyf * g * xf, axis=-1, keepdims=True)     # fp32 reduction
    dx = g * inv * dyf - xf * (inv ** 3) * (s / d)
    dscale = jnp.sum(dyf * xf * inv,
                     axis=tuple(range(x.ndim - 1)))
    return dx.astype(dy.dtype), dscale.astype(jnp.float32)


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(params: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with the (1 + scale) parameterization (gemma/llama style).

    custom_vjp: fp32 statistics, compute-dtype streams in both directions.
    """
    return _rmsnorm_core(x, params["scale"], eps)


def init_layernorm(d: int) -> Dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: Dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (params["scale"] * normed + params["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP.
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, bias: bool = False) -> Dict:
    p = {"kernel": he_init(key, (d_in, d_out))}
    if bias:
        p["bias"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["kernel"].astype(x.dtype)
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y


def init_mlp(key, d: int, d_ff: int, variant: str,
             fused: bool = False) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if variant in ("swiglu", "geglu"):
        if fused:
            return {"wi_fused": init_dense(k1, d, 2 * d_ff),
                    "wo": init_dense(k3, d_ff, d)}
        return {"wi_gate": init_dense(k1, d, d_ff),
                "wi_up": init_dense(k2, d, d_ff),
                "wo": init_dense(k3, d_ff, d)}
    return {"wi": init_dense(k1, d, d_ff), "wo": init_dense(k2, d_ff, d)}


def mlp(params: Dict, x: jnp.ndarray, variant: str, ctx=None) -> jnp.ndarray:
    if variant in ("swiglu", "geglu"):
        act = jax.nn.silu if variant == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        if "wi_fused" in params:
            both = dense(params["wi_fused"], x)
            gate, up = jnp.split(both, 2, axis=-1)
        else:
            gate = dense(params["wi_gate"], x)
            up = dense(params["wi_up"], x)
        h = act(gate) * up
        if ctx is not None:
            h = ctx.constrain(h, "ffn_bsf")
        return dense(params["wo"], h)
    h = jax.nn.gelu(dense(params["wi"], x), approximate=True)
    if ctx is not None:
        h = ctx.constrain(h, "ffn_bsf")
    return dense(params["wo"], h)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + qwen2-vl M-RoPE).
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] int32."""
    freqs = rope_frequencies(x.shape[-1], theta)                # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


MROPE_SECTION_FRACTIONS = (0.25, 0.375, 0.375)   # temporal, height, width


def apply_mrope(x: jnp.ndarray, positions_3d: jnp.ndarray,
                theta: float) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, D]; positions_3d: [3, B, S] (temporal, height, width ids).
    The D/2 frequency slots are partitioned into three sections, each rotated
    by its own position stream.
    """
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)                 # [half]
    sec_t = int(half * MROPE_SECTION_FRACTIONS[0])
    sec_h = int(half * MROPE_SECTION_FRACTIONS[1])
    bounds = (sec_t, sec_t + sec_h)
    slot = jnp.arange(half)
    which = (slot >= bounds[0]).astype(jnp.int32) + \
        (slot >= bounds[1]).astype(jnp.int32)                    # [half] 0/1/2
    pos = positions_3d.astype(jnp.float32)                       # [3, B, S]
    # Select per-slot position stream: [B, S, half]
    pos_sel = jnp.take(pos, which, axis=0)                       # [half,B,S]->?
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)                       # [B, S, half]
    angles = pos_sel * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal position table [seq, d]."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding.
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int) -> Dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(params: Dict, tokens: jnp.ndarray, scale: bool = False,
          dtype=COMPUTE_DTYPE) -> jnp.ndarray:
    x = params["table"].astype(dtype)[tokens]
    if scale:
        x = x * jnp.asarray(math.sqrt(params["table"].shape[1]), dtype)
    return x


def unembed(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Logits via the (tied or separate) output table: [.., d] -> [.., V]."""
    return x @ params["table"].astype(x.dtype).T
