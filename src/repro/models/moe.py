"""Mixture-of-Experts FFN: the paper's blocked-sparsity regime in production.

Token->expert assignment makes the expert FFN a *block-diagonal* SpMM
(DESIGN.md Section 6): after bucketing tokens by expert, each expert's weight
matrix multiplies a dense block of tokens — the best case of the paper's
blocked model (z = t, MXU utilization 1).  On real TPUs the per-expert
matmuls run through the grouped_matmul Pallas kernel (repro.kernels); the
pjit path below expresses the same computation with scatter/gather dispatch
so that *no fake FLOPs* appear in the compiled HLO (a one-hot dispatch einsum
would add O(T*E*C*d) bogus compute and poison the roofline analysis).

Two paths:
  moe_ffn_dense    oracle: every expert computes every token, combined by
                   router weights (tiny configs / tests only).
  moe_ffn          production: shard_map over (data..., model) — tokens are
                   replicated across the model axis (they arrive that way in
                   Megatron-style TP), each model shard owns E/TP experts,
                   selects + buckets its tokens locally (capacity C), runs
                   the expert FFN, and psums partial outputs across "model".
                   Expert weights are stored sharded (E over model, d_model
                   over data) and all-gathered over "data" per layer (FSDP).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


def init_moe(key, d: int, d_ff: int, num_experts: int) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": L.init_dense(k1, d, num_experts),
        "w_gate": L.he_init(k2, (num_experts, d, d_ff), fan_in=d),
        "w_up": L.he_init(k3, (num_experts, d, d_ff), fan_in=d),
        "w_down": L.he_init(k4, (num_experts, d_ff, d), fan_in=d_ff),
    }


def _router(router_params: Dict, x: jnp.ndarray, k: int):
    """Top-k routing. x: [T, d] -> (weights [T,k] f32, ids [T,k] i32)."""
    logits = (x.astype(jnp.float32)
              @ router_params["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, ids.astype(jnp.int32)


def _expert_ffn(w_gate, w_up, w_down, buf: jnp.ndarray) -> jnp.ndarray:
    """buf: [E_loc, C, d] -> [E_loc, C, d] (SwiGLU), batched over experts.

    This is the block-diagonal BCSR SpMM; on TPU it maps to
    kernels.grouped_matmul with group blocks of C rows.
    """
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _capacity(t_local: int, k: int, num_experts: int,
              capacity_factor: float) -> int:
    c = int((t_local * k * capacity_factor) / num_experts) + 1
    c = max(c, min(8, t_local * k))
    return min(c, t_local * k)


def _bucket_local(x, weights, ids, e0: int, e_loc: int, capacity: int):
    """Bucket tokens routed to experts [e0, e0+e_loc) into a capacity buffer.

    x: [T, d]; weights/ids: [T, k].  Returns (buffer [E_loc, C, d],
    combine spec (e_idx, c_idx, keep*w) each [T, k]).
    Pure gather/scatter — no arithmetic beyond the cumsum bookkeeping.
    """
    T, d = x.shape
    k = ids.shape[1]
    local = (ids >= e0) & (ids < e0 + e_loc)
    e_local = jnp.clip(ids - e0, 0, e_loc - 1)

    # Position of each (token, slot) within its expert, counted over the
    # flattened slot-major order (GShard-style sequential ranks).
    pos = jnp.zeros((T, k), jnp.int32)
    counts = jnp.zeros((e_loc,), jnp.int32)
    for r in range(k):
        onehot = (jnp.arange(e_loc)[None, :] == e_local[:, r][:, None])
        onehot = onehot & local[:, r][:, None]          # [T, E_loc] bool
        within = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
        pos = pos.at[:, r].set(
            jnp.take_along_axis(within + counts[None, :],
                                e_local[:, r][:, None], axis=1)[:, 0])
        counts = counts + jnp.sum(onehot.astype(jnp.int32), axis=0)

    keep = local & (pos < capacity)
    buf = jnp.zeros((e_loc, capacity, d), x.dtype)
    flat_e = jnp.where(keep, e_local, 0).reshape(-1)
    flat_c = jnp.where(keep, pos, 0).reshape(-1)
    updates = jnp.repeat(x[:, None, :], k, axis=1).reshape(-1, d)
    updates = updates * keep.reshape(-1, 1).astype(x.dtype)
    buf = buf.at[flat_e, flat_c].add(updates)
    return buf, (e_local, jnp.clip(pos, 0, capacity - 1),
                 weights * keep.astype(weights.dtype))


def _combine_local(out_buf, combine, T: int) -> jnp.ndarray:
    e_idx, c_idx, w = combine                      # each [T, k]
    gathered = out_buf[e_idx, c_idx]               # [T, k, d]
    return jnp.sum(gathered * w[..., None].astype(gathered.dtype), axis=1)


def _moe_local(x, router, w_gate, w_up, w_down, *, k: int, num_experts: int,
               e0: int, capacity_factor: float) -> jnp.ndarray:
    """Per-device MoE over this shard's experts; x: [T, d] local tokens."""
    T, d = x.shape
    e_loc = w_gate.shape[0]
    weights, ids = _router(router, x, k)
    cap = _capacity(T, k, num_experts, capacity_factor)
    buf, combine = _bucket_local(x, weights, ids, e0, e_loc, cap)
    out_buf = _expert_ffn(w_gate.astype(x.dtype), w_up.astype(x.dtype),
                          w_down.astype(x.dtype), buf)
    return _combine_local(out_buf, combine, T)


def moe_ffn(params: Dict, x: jnp.ndarray, *, k: int, num_experts: int,
            capacity_factor: float = 1.25, ctx=None) -> jnp.ndarray:
    """MoE FFN. x: [B, S, d].  Uses shard_map when ctx carries a mesh."""
    B, S, d = x.shape
    mesh = getattr(ctx, "mesh", None)
    if mesh is None or "model" not in mesh.axis_names:
        flat = x.reshape(B * S, d)
        out = _moe_local(flat, params["router"], params["w_gate"],
                         params["w_up"], params["w_down"], k=k,
                         num_experts=num_experts, e0=0,
                         capacity_factor=capacity_factor)
        return out.reshape(B, S, d)

    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    tp = mesh.shape["model"]
    assert num_experts % tp == 0, (num_experts, tp)
    # Weights are FSDP-sharded over "data" only (replicated across "pod" —
    # hybrid ZeRO, DESIGN.md Section 4); gathered per layer inside the block.
    w_ax = "data" if ("data" in mesh.axis_names
                      and mesh.shape["data"] > 1) else None

    # Sequence-scatter the combined output when the local seq divides TP:
    # the layer boundary is seq-sharded anyway (Megatron SP), so psum +
    # re-shard would move TP x more bytes than psum_scatter.
    seq_local = S
    scatter_ok = seq_local % tp == 0 and seq_local > 1

    def shard_fn(x_loc, router, w_gate, w_up, w_down):
        if w_ax is not None:
            # FSDP all-gather in the compute dtype: gathering fp32 masters
            # doubles the dominant collective of the MoE cells
            # (EXPERIMENTS.md Section Perf, hypothesis P5).
            w_gate = jax.lax.all_gather(
                w_gate.astype(x_loc.dtype), w_ax, axis=1, tiled=True)
            w_up = jax.lax.all_gather(
                w_up.astype(x_loc.dtype), w_ax, axis=1, tiled=True)
            w_down = jax.lax.all_gather(
                w_down.astype(x_loc.dtype), w_ax, axis=2, tiled=True)
        e_loc = w_gate.shape[0]
        e0 = jax.lax.axis_index("model") * e_loc
        Bl, Sl, _ = x_loc.shape
        out = _moe_local(x_loc.reshape(Bl * Sl, d), router, w_gate, w_up,
                         w_down, k=k, num_experts=num_experts, e0=e0,
                         capacity_factor=capacity_factor)
        out = out.reshape(Bl, Sl, d)
        if scatter_ok:
            # Row-parallel partial sums -> sequence shards (SP boundary).
            return jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(out, "model")

    out_spec = P(batch_axes, "model", None) if scatter_ok \
        else P(batch_axes, None, None)
    return jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axes, None, None),               # x
                  P(),                                     # router (replic.)
                  P("model", w_ax, None),                  # w_gate [E,d,ff]
                  P("model", w_ax, None),                  # w_up
                  P("model", None, w_ax)),                 # w_down [E,ff,d]
        out_specs=out_spec,
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])


def moe_ffn_dense(params: Dict, x: jnp.ndarray, *, k: int,
                  num_experts: int) -> jnp.ndarray:
    """Oracle: compute all experts for all tokens (tests / tiny configs)."""
    B, S, d = x.shape
    flat = x.reshape(B * S, d)
    weights, ids = _router(params["router"], flat, k)
    w_gate = params["w_gate"].astype(x.dtype)
    w_up = params["w_up"].astype(x.dtype)
    w_down = params["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", flat, w_gate)) * \
        jnp.einsum("td,edf->tef", flat, w_up)
    all_out = jnp.einsum("tef,efd->ted", h, w_down)       # [T, E, d]
    gate = jnp.zeros((flat.shape[0], num_experts), jnp.float32)
    gate = gate.at[jnp.arange(flat.shape[0])[:, None], ids].add(weights)
    out = jnp.einsum("ted,te->td", all_out.astype(jnp.float32), gate)
    return out.reshape(B, S, d).astype(x.dtype)


def sparse_component_spec(cfg, shape, t_tokens: int) -> Dict:
    """Paper-model metadata for the analyzer: MoE as blocked sparsity.

    A = token x token-slot block-diagonal matrix: one t x t dense block per
    capacity bucket; d = d_model (the dense operand width).
    """
    return {
        "name": f"moe_dispatch/{cfg.name}",
        "regime": "blocked_tpu",
        "n": t_tokens * cfg.num_experts_per_token,
        "nnz": t_tokens * cfg.num_experts_per_token * 128,
        "t": 128,
        "num_blocks": max(
            1, t_tokens * cfg.num_experts_per_token // 128),
        "d": cfg.d_model,
        "sizeof_val": 2,
    }
