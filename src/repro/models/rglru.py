"""RG-LRU recurrent block (Griffin / recurrentgemma).

Gated linear recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t).  The state is a single vector
per channel (no state-dim expansion like mamba), so the full-sequence
associative scan fits in activation memory directly.

Gates use the Griffin block-diagonal parameterization (NUM_GATE_BLOCKS
diagonal blocks) — this is itself the paper's blocked-sparsity regime
applied to a weight matrix.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.ssm import _causal_conv, _ssm_combine

NUM_GATE_BLOCKS = 16
_C = 8.0


def init_rglru(key, d: int, rw: int, conv: int = 4) -> Dict:
    keys = jax.random.split(key, 6)
    nb = NUM_GATE_BLOCKS
    bs = rw // nb
    return {
        "wx": L.init_dense(keys[0], d, rw),
        "wy": L.init_dense(keys[1], d, rw),      # gelu branch
        "conv_w": L.he_init(keys[2], (conv, rw), fan_in=conv),
        "conv_b": jnp.zeros((rw,), jnp.float32),
        "w_r": L.he_init(keys[3], (nb, bs, bs), fan_in=bs),
        "w_i": L.he_init(keys[4], (nb, bs, bs), fan_in=bs),
        "lam": jnp.linspace(0.5, 4.0, rw).astype(jnp.float32),
        "out": L.init_dense(keys[5], rw, d),
    }


def _block_diag(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x: [..., rw] @ block-diagonal w: [nb, bs, bs] -> [..., rw]."""
    nb, bs, _ = w.shape
    xb = x.reshape(*x.shape[:-1], nb, bs)
    out = jnp.einsum("...nb,nbc->...nc", xb, w.astype(x.dtype))
    return out.reshape(*x.shape)


def _gates(params: Dict, xb: jnp.ndarray):
    r = jax.nn.sigmoid(_block_diag(params["w_r"], xb).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(params["w_i"], xb).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i \
        * xb.astype(jnp.float32)
    return a, gated


def rglru_forward(params: Dict, x: jnp.ndarray, ctx=None,
                  chunk: int = 512) -> jnp.ndarray:
    """x: [B,S,d] -> [B,S,d].

    Chunked associative scan: the fp32 gate tensors (a, sqrt(1-a^2)*i*x)
    materialize per ``chunk`` timesteps only, with a sequential carry
    between chunks — a full-sequence scan held 4 fp32 [B,S,rw] tensors
    live and blew the remat budget on the 4k train cells (59.6 GiB/chip;
    EXPERIMENTS.md Section Perf, P8).  Same math: the first element of
    each chunk folds the carry in, exactly like the mamba chunk scan.
    """
    B, S, d = x.shape
    branch = jax.nn.gelu(L.dense(params["wy"], x), approximate=True)
    xb = L.dense(params["wx"], x)
    xb = _causal_conv(xb, params["conv_w"], params["conv_b"])
    if ctx is not None:
        xb = ctx.constrain(xb, "ssm_bsdn")
    rw = xb.shape[-1]
    ch = min(chunk, S)
    if S % ch:
        ch = S  # fall back to one chunk for odd lengths (smoke tests)
    xb_chunks = jnp.moveaxis(xb.reshape(B, S // ch, ch, rw), 1, 0)

    def chunk_step(h, xb_c):
        a, gated = _gates(params, xb_c)
        gated = gated.at[:, 0].add(a[:, 0] * h)
        _, hs = jax.lax.associative_scan(_ssm_combine, (a, gated), axis=1)
        return hs[:, -1], hs.astype(x.dtype)

    h0 = jnp.zeros((B, rw), jnp.float32)
    _, h_chunks = jax.lax.scan(chunk_step, h0, xb_chunks)
    h = jnp.moveaxis(h_chunks, 0, 1).reshape(B, S, rw)
    return L.dense(params["out"], h * branch)


def init_rglru_cache(params: Dict, batch: int) -> Dict:
    conv, rw = params["conv_w"].shape
    return {
        "conv": jnp.zeros((batch, conv - 1, rw), jnp.bfloat16),
        "h": jnp.zeros((batch, rw), jnp.float32),
    }


def rglru_decode(params: Dict, cache: Dict,
                 x: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """x: [B,1,d] -> ([B,1,d], cache')."""
    branch = jax.nn.gelu(L.dense(params["wy"], x), approximate=True)
    xb_raw = L.dense(params["wx"], x)                      # [B,1,rw]
    xb = _causal_conv(xb_raw, params["conv_w"], params["conv_b"],
                      state=cache["conv"])
    new_conv = jnp.concatenate(
        [cache["conv"][:, 1:], xb_raw.astype(cache["conv"].dtype)], axis=1)
    a, gated = _gates(params, xb[:, 0])
    h = a * cache["h"] + gated
    out = L.dense(params["out"], h[:, None, :].astype(x.dtype) * branch)
    return out, {"conv": new_conv, "h": h}
