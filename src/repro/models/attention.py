"""Attention: chunked (flash-style) prefill/train paths + decode paths.

Three structural regimes, matching the paper's taxonomy as applied to
attention maps (DESIGN.md Section 6):

  global causal   -> dense lower-triangular map (random/scale-free regime
                     when sparsified; dense roofline here)
  local (window)  -> banded map: the paper's diagonal-sparsity regime; the
                     kv working set per query block is a fixed band, realized
                     by dynamic-slice gathers instead of full-seq scans
  bidirectional   -> encoder / cross attention (dense rectangular)

All softmax statistics are fp32; activations bf16.  The chunked paths scan
over blocks so HLO size is O(1) in sequence length and peak memory is
O(block * seq_kv_block) — required for the 32k prefill cells.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30

#: Causal-attention implementation: "masked" scans every (q, kv) block pair
#: and masks the upper triangle (2x FLOP waste, simple); "triangle" scans
#: only the lower-triangular pairs (exact FLOPs).  Module-level so launch
#: scripts can flip it per experiment (EXPERIMENTS.md Section Perf).
CAUSAL_IMPL = "masked"


def set_causal_impl(impl: str) -> None:
    global CAUSAL_IMPL
    assert impl in ("masked", "triangle"), impl
    CAUSAL_IMPL = impl


def _pick_block(s: int, pref: int) -> int:
    """Largest block size <= pref that divides s (shapes are static)."""
    b = min(pref, s)
    while s % b:
        b -= 1
    return b


def _gqa_expand(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """[B,S,Hq,D] -> [B,S,Hkv,G,D] grouping query heads per kv head."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def _attn_block(q, k, v, mask) -> Tuple[jnp.ndarray, jnp.ndarray,
                                        jnp.ndarray]:
    """One (q-block, kv-block) tile: returns (unnormalized out, m, l).

    q: [B, bq, Hkv, G, D]; k/v: [B, bk, Hkv, D]; mask: [bq, bk] or None.
    Tiles stay in the compute dtype (bf16) with fp32 accumulation — the
    fp32-tile variant doubled the attention-interior HBM traffic
    (EXPERIMENTS.md Section Perf, hypothesis P1).
    """
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32)
    # m is the max over *unmasked* logits — an upper bound on the masked
    # max, equally valid for stability, and it lets mask+exp+cast fuse into
    # a single elementwise pass over the logits (one bf16 tensor written
    # instead of two fp32 ones; EXPERIMENTS.md Section Perf, P6).
    m = jnp.max(logits, axis=-1)                          # [B,H,G,bq]
    p = jnp.exp(logits - m[..., None])
    if mask is not None:
        p = jnp.where(mask[None, None, None], p, 0.0)
    p = p.astype(v.dtype)
    l = jnp.sum(p, axis=-1, dtype=jnp.float32)            # [B,H,G,bq]
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v,
                     preferred_element_type=jnp.float32)
    return out, m, l


def _merge(acc, m_acc, l_acc, out, m, l):
    """Online-softmax merge of a new tile into the accumulators."""
    m_new = jnp.maximum(m_acc, m)
    scale_old = jnp.exp(m_acc - m_new)
    scale_new = jnp.exp(m - m_new)
    acc = acc * scale_old[..., None] + out * scale_new[..., None]
    l_new = l_acc * scale_old + l * scale_new
    return acc, m_new, l_new


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, q_block: int = 512,
                      kv_block: int = 1024) -> jnp.ndarray:
    """Global (or bidirectional) chunked attention.

    q: [B,Sq,Hq,D]; k/v: [B,Skv,Hkv,D].  Causal masking assumes q and k
    positions align at the end (standard LM layout, Sq == Skv for training).

    Baseline note (EXPERIMENTS.md Section Perf): the causal path scans every
    (q, kv) block pair and masks the upper triangle, so HLO FLOPs are ~2x the
    useful attention FLOPs.  The banded/local path below has no such waste.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    bq = _pick_block(sq, q_block)
    if causal and CAUSAL_IMPL == "triangle" and sq == skv:
        kv_block = bq          # triangle walks square block pairs
    bk = _pick_block(skv, kv_block)
    nq, nk = sq // bq, skv // bk

    qe = _gqa_expand(q, hkv) * (1.0 / math.sqrt(d))
    qe = jnp.moveaxis(qe.reshape(b, nq, bq, hkv, hq // hkv, d), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nk, bk, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, bk, hkv, d), 1, 0)

    if causal and CAUSAL_IMPL == "triangle" and bq == bk and nq == nk:
        out = _triangle_causal(qe, kb, vb, b, hq, hkv, d, nq, bq)
        return jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, d)

    q_pos = jnp.arange(bq)
    k_pos = jnp.arange(bk)

    def q_step(_, qi_and_i):
        qi, i = qi_and_i

        def kv_step(carry, kv_and_j):
            acc, m_acc, l_acc = carry
            (kj, vj), j = kv_and_j
            mask = None
            if causal:
                abs_q = i * bq + q_pos[:, None]
                abs_k = j * bk + k_pos[None, :]
                mask = abs_q >= abs_k
            out, m, l = _attn_block(qi, kj, vj, mask)
            return _merge(acc, m_acc, l_acc, out, m, l), None

        g = hq // hkv
        init = (jnp.zeros((b, hkv, g, bq, d), jnp.float32),
                jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, g, bq), jnp.float32))
        (acc, _, l_acc), _ = jax.lax.scan(
            kv_step, init, ((kb, vb), jnp.arange(nk)))
        out = acc / jnp.maximum(l_acc[..., None], 1e-30)
        # [B,Hkv,G,bq,D] -> [B,bq,Hq,D]
        out = jnp.moveaxis(out, 3, 1).reshape(b, bq, hq, d)
        return None, out.astype(q.dtype)

    # Flash-attention memory semantics: the per-q-block step is
    # rematerialized in backward, so no per-(q,kv)-block probabilities are
    # ever saved — O(seq) residuals instead of O(seq^2).
    _, blocks = jax.lax.scan(jax.checkpoint(q_step), None,
                             (qe, jnp.arange(nq)))
    return jnp.moveaxis(blocks, 0, 1).reshape(b, sq, hq, d)


def _triangle_causal(qe, kb, vb, b, hq, hkv, d, nq, bq):
    """Exact-FLOP causal flash: scan only lower-triangular block pairs.

    qe: [nq, B, bq, Hkv, G, D]; kb/vb: [nq, B, bq, Hkv, D].
    The (i, j<=i) pairs are enumerated row-major so all updates to output
    block i are consecutive; accumulators live in the scan carry and are
    updated with dynamic slices.  FLOPs = nq(nq+1)/2 block tiles — no
    masked-out upper-triangle compute (EXPERIMENTS.md Section Perf, P3).
    """
    g = hq // hkv
    pairs_i, pairs_j = [], []
    for i in range(nq):
        for j in range(i + 1):
            pairs_i.append(i)
            pairs_j.append(j)
    idx_i = jnp.asarray(pairs_i, jnp.int32)
    idx_j = jnp.asarray(pairs_j, jnp.int32)
    tri = jnp.arange(bq)[:, None] >= jnp.arange(bq)[None, :]

    def step(carry, ij):
        acc, m_acc, l_acc = carry
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qe, i, 0, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
        # Only the diagonal block needs the triangular mask.
        mask = jnp.where(i == j, tri, jnp.ones_like(tri))
        out, m, l = _attn_block(qi, kj, vj, mask)
        a_i = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_i = jax.lax.dynamic_index_in_dim(m_acc, i, 0, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l_acc, i, 0, keepdims=False)
        a_n, m_n, l_n = _merge(a_i, m_i, l_i, out, m, l)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_n, i, 0)
        m_acc = jax.lax.dynamic_update_index_in_dim(m_acc, m_n, i, 0)
        l_acc = jax.lax.dynamic_update_index_in_dim(l_acc, l_n, i, 0)
        return (acc, m_acc, l_acc), None

    init = (jnp.zeros((nq, b, hkv, g, bq, d), jnp.float32),
            jnp.full((nq, b, hkv, g, bq), NEG_INF, jnp.float32),
            jnp.zeros((nq, b, hkv, g, bq), jnp.float32))
    (acc, _, l_acc), _ = jax.lax.scan(jax.checkpoint(step), init,
                                      (idx_i, idx_j))
    out = acc / jnp.maximum(l_acc[..., None], 1e-30)
    # [nq,B,Hkv,G,bq,D] -> [nq,B,bq,Hq,D]
    out = jnp.moveaxis(out, 4, 2)
    return out.reshape(nq, b, bq, hq, d).astype(qe.dtype)


def local_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    window: int, q_block: int = 512) -> jnp.ndarray:
    """Sliding-window causal attention (the paper's banded regime).

    Each q block attends to a fixed band [i*bq - window + 1, i*bq + bq), so
    the kv working set is gathered with one dynamic slice per block: traffic
    and FLOPs scale with window, not seq — exactly the diagonal-sparsity
    argument of Eq. 3.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    bq = _pick_block(s, q_block)
    nq = s // bq
    band = bq + window  # kv slice length per q block (rounded band)

    # Pad kv on the left so every slice is in-bounds.
    pad = band - bq
    kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    qe = _gqa_expand(q, hkv) * (1.0 / math.sqrt(d))
    qe = jnp.moveaxis(qe.reshape(b, nq, bq, hkv, hq // hkv, d), 1, 0)

    q_pos = jnp.arange(bq)
    k_pos = jnp.arange(band)

    def q_step(_, qi_and_i):
        qi, i = qi_and_i
        start = i * bq  # left edge of the band in padded coords
        kj = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        # Absolute positions: q at start+pad-…; do it in band-relative terms:
        # kv slot t corresponds to absolute position start + t - pad.
        abs_q = q_pos[:, None] + pad          # within-band coords of queries
        abs_k = k_pos[None, :]
        mask = (abs_q >= abs_k) & (abs_q - abs_k < window)
        # Mask out padded (absolute < 0) kv slots.
        valid = (start + k_pos - pad) >= 0
        mask = mask & valid[None, :]
        out, m, l = _attn_block(qi, kj, vj, mask)
        out = out / jnp.maximum(l[..., None], 1e-30)
        out = jnp.moveaxis(out, 3, 1).reshape(b, bq, hq, d)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(jax.checkpoint(q_step), None,
                             (qe, jnp.arange(nq)))
    return jnp.moveaxis(blocks, 0, 1).reshape(b, s, hq, d)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray,
                     slot_mask: jnp.ndarray) -> jnp.ndarray:
    """One-token attention against a cache.

    q: [B,1,Hq,D]; caches: [B,S,Hkv,D]; slot_mask: [B,S] bool (valid slots).
    Works for both linear caches (prefix valid) and ring buffers (arbitrary
    valid set — softmax is permutation-invariant).
    """
    b, _, hq, d = q.shape
    hkv = k_cache.shape[2]
    qe = _gqa_expand(q, hkv)[:, 0] * (1.0 / math.sqrt(d))   # [B,Hkv,G,D]
    logits = jnp.einsum("bhgd,bshd->bhgs", qe.astype(jnp.float32),
                        k_cache.astype(jnp.float32))
    logits = jnp.where(slot_mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)
