"""Activation-sharding context threaded through the model zoo.

Models never import mesh/axis names; they call ``ctx.constrain(x, kind)``
with a semantic activation kind and the launch layer decides the actual
PartitionSpec (launch/sharding.py).  The default context is a no-op so smoke
tests and single-device runs need no mesh.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax


class ShardingCtx:
    """Maps semantic activation kinds to sharding constraints."""

    #: Semantic kinds used by the model zoo.
    KINDS = (
        "tokens_bse",    # residual stream [batch, seq, d_model]
        "heads_bshd",    # attention activations [batch, seq, heads, hd]
        "kv_bskd",       # key/value activations [batch, seq, kv_heads, hd]
        "kv_cache",      # decode KV cache [batch, kv_heads, seq, hd]
        "logits_bsv",    # LM head output [batch, seq, vocab]
        "ffn_bsf",       # MLP hidden [batch, seq, d_ff]
        "moe_gecd",      # dispatched expert buffer [groups, experts, cap, d]
        "moe_gecf",      # expert FFN hidden [groups, experts, cap, ff]
        "ssm_bsdn",      # SSM inner state activations [batch, seq, d_in(, N)]
    )

    def __init__(self, rules: Optional[Dict[str, object]] = None,
                 mesh: Optional[object] = None):
        self.rules = rules or {}
        self.mesh = mesh

    def constrain(self, x: jax.Array, kind: str) -> jax.Array:
        spec = self.rules.get(kind)
        if spec is None or self.mesh is None:
            return x
        if x.ndim != len(spec):
            return x  # rank mismatch (e.g. flattened variant): skip
        # Drop sharding on dims the mesh does not evenly divide (total
        # policy; mirrors launch.sharding.validate_spec).
        fixed = []
        for i, axes in enumerate(tuple(spec)):
            if axes is None:
                fixed.append(None)
                continue
            axes_t = axes if isinstance(axes, tuple) else (axes,)
            factor = 1
            for a in axes_t:
                factor *= self.mesh.shape[a]
            fixed.append(axes if x.shape[i] % factor == 0 else None)
        spec = jax.sharding.PartitionSpec(*fixed)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


NO_SHARDING = ShardingCtx()
