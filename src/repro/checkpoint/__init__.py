"""repro.checkpoint"""
