"""Fault-tolerant checkpointing: atomic, manifest-verified, elastic.

Layout (one directory per step):
    <root>/step_000042/
        manifest.json         {path: {shape, dtype}} + step + wallclock
        arrays/<flat.key>.npy one file per leaf (full, unsharded array)
        COMMITTED             sentinel written last (atomicity marker)

Design points for pod-scale fault tolerance:
  * Atomicity: arrays are written to ``<dir>.tmp`` then the directory is
    renamed and the COMMITTED sentinel written; a crash mid-write leaves a
    .tmp that restore() ignores.  ``latest_step`` only returns committed
    checkpoints, so restart after any failure is safe.
  * Elasticity: leaves are stored unsharded, so a restart may use a
    different mesh/topology — restore() device_puts with the *new* sharding
    (resharding on load).  At true scale this becomes per-shard files with
    an index; the manifest layout already carries everything needed.
  * Retention: keep the newest ``keep`` committed checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    out[prefix.rstrip("/")] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Dict:
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


class Checkpointer:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def save(self, step: int, tree: Dict) -> str:
        """Write a committed checkpoint for ``step``; returns its path."""
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        arrays_dir = os.path.join(tmp, "arrays")
        os.makedirs(arrays_dir)
        flat = _flatten(tree)
        manifest = {"step": step, "time": time.time(), "arrays": {}}
        for key, val in flat.items():
            arr = np.asarray(jax.device_get(val))
            fname = key.replace("/", ".") + ".npy"
            np.save(os.path.join(arrays_dir, fname), arr)
            manifest["arrays"][key] = {"file": fname,
                                       "shape": list(arr.shape),
                                       "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # Sentinel last: a rename is atomic on POSIX, the sentinel guards
        # against non-atomic network filesystems.
        with open(os.path.join(final, "COMMITTED"), "w") as f:
            f.write(str(step))
        self._gc()
        return final

    # ------------------------------------------------------------------
    def committed_steps(self):
        steps = []
        for name in os.listdir(self.root):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.root, name, "COMMITTED")):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[Dict] = None,
                like: Optional[Dict] = None) -> Dict:
        """Load a checkpoint.

        shardings: optional pytree (or flat dict) of NamedSharding to
        device_put each leaf with — this is where elastic resharding
        happens (the stored arrays are topology-free).
        like: optional pytree whose dtypes/structure to validate against.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint in "
                                        f"{self.root}")
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_shard = _flatten(shardings) if isinstance(shardings, dict) \
            else None
        flat = {}
        for key, meta in manifest["arrays"].items():
            arr = np.load(os.path.join(d, "arrays", meta["file"]))
            if flat_shard and key in flat_shard and \
                    flat_shard[key] is not None:
                flat[key] = jax.device_put(arr, flat_shard[key])
            else:
                flat[key] = arr
        tree = _unflatten(flat)
        if like is not None:
            jax.tree_util.tree_structure(like)  # raises on mismatch below
            flat_like = _flatten(like)
            missing = set(flat_like) - set(flat)
            if missing:
                raise ValueError(f"checkpoint step {step} missing leaves: "
                                 f"{sorted(missing)[:5]}...")
        return tree

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
