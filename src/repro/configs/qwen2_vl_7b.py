"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
[arXiv:2409.12191; hf]  Backbone only per the assignment: input_specs()
provides precomputed patch embeddings merged into the token stream; M-RoPE
position ids are 3D (temporal, height, width).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    head_dim=128,
    mlp_variant="swiglu",
    qkv_bias=True,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    mrope=True,
    supports_long_context=False,  # full attention
    source="arXiv:2409.12191; hf",
))
