"""falcon-mamba-7b [ssm] — mamba1 architecture, attention-free.

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16.
[arXiv:2410.05355; unverified]  Pure selective-SSM stack; constant-size
recurrent state makes every long-context cell runnable.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    head_dim=0,
    layer_pattern=("ssm",),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=False,
    supports_long_context=True,
    source="arXiv:2410.05355; unverified",
))
