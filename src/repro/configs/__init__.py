"""Architecture and experiment configs."""
from repro.configs.base import (
    ARCH_MODULES, ModelConfig, SHAPES, ShapeConfig, all_cells, get_config,
    list_archs, register,
)
__all__ = ["ARCH_MODULES", "ModelConfig", "SHAPES", "ShapeConfig",
           "all_cells", "get_config", "list_archs", "register"]
