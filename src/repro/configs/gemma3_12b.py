"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]  Pattern period: 5 sliding-window
layers (1024 window) then 1 global layer.  The hybrid pattern bounds the
KV cache of 5/6 of the layers, so long_500k is runnable with the global
layers' cache sequence-sharded (see DESIGN.md Section 7).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262_144,
    head_dim=256,
    mlp_variant="geglu",
    tie_embeddings=True,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=1024,
    rope_theta=1_000_000.0,
    supports_long_context=True,
    source="hf:google/gemma-3-1b-pt; unverified",
))
