"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 ratio.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000.
[arXiv:2402.19427; unverified]  Griffin layout: two recurrent (RG-LRU)
blocks followed by one local-attention block; 38 layers = (2+1) does not
divide 38, so per the Griffin paper the final pattern truncates — we round
to the nearest pattern-aligned depth (39 -> 38 is not period-aligned, we
keep 38 via period (rglru, rglru, local) x 12 + 2 extra rglru folded as a
13th truncated group; implemented as 36 pattern layers + 2 rglru by using
period-aligned 36? No: we keep EXACTLY 38 layers by using a pattern of
length 19 (12 full (r,r,l) groups + (r,r)) repeated twice).
"""
from repro.configs.base import ModelConfig, register

_PERIOD = (("rglru", "rglru", "local") * 6 + ("rglru",))  # 19 layers

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    mlp_variant="geglu",
    tie_embeddings=True,
    layer_pattern=_PERIOD,
    window_size=2048,
    rnn_width=4096,
    supports_long_context=True,   # bounded window + constant RG-LRU state
    source="arXiv:2402.19427; unverified",
))
