"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ModelConfig``; the shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are ``ShapeConfig``s.
``MODEL_FLOPS`` accounting (6*N*D convention + attention term, active-only
for MoE) lives here so the roofline analyzer, trainer logging and benchmarks
all agree on "useful FLOPs".
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Shape cells (assigned input-shape set for the LM family).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. Field defaults match a vanilla dense decoder."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # None => d_model // num_heads
    mlp_variant: str = "swiglu"     # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = True
    # Attention layout: repeating period of layer kinds.
    layer_pattern: Tuple[str, ...] = ("global",)   # global|local|rglru|ssm
    window_size: int = 4_096
    rope_theta: float = 10_000.0
    mrope: bool = False             # qwen2-vl 3D M-RoPE
    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # RG-LRU (griffin/recurrentgemma)
    rnn_width: int = 0
    # Encoder-decoder (whisper): encoder stack + stubbed frontend frames.
    encoder_layers: int = 0
    encoder_seq: int = 0
    # Capability flags.
    supports_long_context: bool = False   # sub-quadratic path exists
    norm_eps: float = 1e-6
    source: str = ""                # provenance tag from the assignment

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))
        if len(self.layer_pattern) and \
                self.num_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"layer_pattern period {len(self.layer_pattern)}")

    # ------------------------------------------------------------------
    # Parameter accounting.
    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table and
        logits shard evenly over any mesh axis (whisper's 51865 is odd)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_head_total(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def d_kv_total(self) -> int:
        return self.num_kv_heads * self.head_dim

    def _mlp_params(self) -> int:
        mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        return mult * self.d_model * self.d_ff

    def _moe_params(self, active: bool) -> int:
        e = self.num_experts_per_token if active else self.num_experts
        expert = 3 * self.d_model * self.moe_d_ff
        router = self.d_model * self.num_experts
        return e * expert + router

    def _attn_params(self) -> int:
        p = self.d_model * self.d_head_total            # Q
        p += 2 * self.d_model * self.d_kv_total         # K, V
        p += self.d_head_total * self.d_model           # O
        if self.qkv_bias:
            p += self.d_head_total + 2 * self.d_kv_total
        return p

    def _ssm_params(self) -> int:
        d_in = self.ssm_expand * self.d_model
        dt_rank = max(self.d_model // 16, 1)
        p = self.d_model * 2 * d_in                     # in_proj (x, z)
        p += d_in * self.ssm_conv                       # conv1d
        p += d_in * (dt_rank + 2 * self.ssm_state)      # x_proj
        p += dt_rank * d_in                             # dt_proj
        p += d_in * self.ssm_state + d_in               # A_log, D
        p += d_in * self.d_model                        # out_proj
        return p

    def _rglru_params(self) -> int:
        rw = self.rnn_width or self.d_model
        p = 2 * self.d_model * rw                       # in proj (x, gate)
        p += rw * self.ssm_conv if self.ssm_conv else 0  # temporal conv
        p += 2 * rw * rw // 16                          # block-diag gates
        p += 2 * rw                                     # a param + bias
        p += rw * self.d_model                          # out proj
        return p

    def _layer_params(self, kind: str, active: bool) -> int:
        norm = 2 * self.d_model
        if kind == "ssm":
            return self._ssm_params() + norm
        if kind == "rglru":
            return self._rglru_params() + self._mlp_params() + norm
        # attention layer (global or local)
        mlp = (self._moe_params(active) if self.num_experts
               else self._mlp_params())
        return self._attn_params() + mlp + norm

    def param_count(self, active: bool = False) -> int:
        period = self.layer_pattern
        per_period = sum(self._layer_params(k, active) for k in period)
        body = per_period * (self.num_layers // len(period))
        if self.encoder_layers:
            enc_layer = self._attn_params() + self._mlp_params() \
                + 2 * self.d_model
            cross = self._attn_params() + self.d_model
            body += self.encoder_layers * enc_layer + self.num_layers * cross
        embed = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            embed *= 2
        return body + embed

    # ------------------------------------------------------------------
    # MODEL_FLOPS (useful FLOPs) per shape cell.
    # ------------------------------------------------------------------
    def _attn_flops_per_token(self, kv_len: int, train: bool) -> float:
        """QK^T + AV matmul FLOPs per token per attention layer."""
        flops = 4.0 * self.num_heads * self.head_dim * kv_len
        if train:
            flops *= 0.5   # causal mask halves the average context
            flops *= 3.0   # fwd + bwd(2x)
        return flops

    def _effective_kv(self, kind: str, seq: int) -> int:
        return min(seq, self.window_size) if kind == "local" else seq

    def model_flops(self, shape: ShapeConfig) -> float:
        """Useful FLOPs for one step of the given shape cell.

        train: 6*N_active*tokens + attention term (fwd+bwd).
        prefill: 2*N_active*tokens + attention term (fwd only).
        decode: one new token per sequence against a seq_len KV cache.
        """
        n_active = self.param_count(active=True)
        n_embed = self.vocab_size * self.d_model
        n_body = n_active - n_embed * (1 if self.tie_embeddings else 2)
        # The LM head matmul is real compute; input embedding lookup is not.
        n_mm = n_body + n_embed

        period = self.layer_pattern
        reps = self.num_layers // len(period)
        attn_kinds = [k for k in period if k in ("global", "local")] * reps

        if shape.kind == "train":
            tokens = shape.seq_len * shape.global_batch
            flops = 6.0 * n_mm * tokens
            for k in attn_kinds:
                kv = self._effective_kv(k, shape.seq_len)
                flops += tokens * self._attn_flops_per_token(kv, train=True)
            return flops
        if shape.kind == "prefill":
            tokens = shape.seq_len * shape.global_batch
            flops = 2.0 * n_mm * tokens
            for k in attn_kinds:
                kv = self._effective_kv(k, shape.seq_len)
                flops += tokens * 0.5 * self._attn_flops_per_token(
                    kv, train=False)
            return flops
        # decode: one token per sequence.
        tokens = shape.global_batch
        flops = 2.0 * n_mm * tokens
        for k in attn_kinds:
            kv = self._effective_kv(k, shape.seq_len)
            flops += tokens * self._attn_flops_per_token(kv, train=False)
        return flops

    # ------------------------------------------------------------------
    def runnable_shapes(self) -> Tuple[str, ...]:
        """Shape cells this architecture can lower (skips documented in
        DESIGN.md Section 7)."""
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context:
            names.append("long_500k")
        return tuple(names)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        period = len(self.layer_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=max(period, 2 if period == 1 else period),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            moe_d_ff=64 if self.num_experts else 0,
            num_experts=min(self.num_experts, 8),
            num_experts_per_token=min(self.num_experts_per_token, 2),
            vocab_size=256,
            rnn_width=64 if self.rnn_width else 0,
            window_size=min(self.window_size, 32),
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_seq else 0,
        )


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, ModelConfig] = {}

ARCH_MODULES = (
    "recurrentgemma_9b", "qwen2_vl_7b", "falcon_mamba_7b", "whisper_base",
    "llama3_2_1b", "gemma3_12b", "gemma_2b", "qwen2_72b",
    "qwen3_moe_235b_a22b", "olmoe_1b_7b",
)


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    if len(_REGISTRY) >= len(ARCH_MODULES):
        return
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: "
                       f"{sorted(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def all_cells():
    """Every runnable (arch, shape) pair — the dry-run matrix."""
    _ensure_loaded()
    for arch in sorted(_REGISTRY):
        cfg = _REGISTRY[arch]
        for shape in cfg.runnable_shapes():
            yield arch, shape
