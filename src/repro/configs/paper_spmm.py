"""The paper's own experiment configuration (Tables III-V).

Defines the matrix suite, dense widths d, and the implementations compared,
at a scale runnable on this container while preserving the out-of-cache
regime the paper requires.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SpMMExperimentConfig:
    scale: int = 16                  # log2(n) for the generated suite
    d_values: Tuple[int, ...] = (1, 4, 16, 64)
    implementations: Tuple[str, ...] = ("csr", "ell", "bcsr", "dia",
                                        "binned", "rowsplit", "ell_coo")
    bcsr_block: int = 64             # t for the CSB-analogue
    dtype: str = "float32"           # paper uses float64; fp32 on this host
    repeats: int = 5                 # timing repeats (min is reported)
    hub_fraction: float = 0.001      # paper: f = 0.1% of nodes


CONFIG = SpMMExperimentConfig()
