"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8.

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936.
[hf:Qwen/Qwen3-30B-A3B; hf]  Every layer is MoE; the expert FFN runs as a
block-diagonal BCSR SpMM (the paper's blocked regime; DESIGN.md Section 6).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=151_936,
    head_dim=128,
    mlp_variant="swiglu",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    num_experts=128,
    num_experts_per_token=8,
    moe_d_ff=1536,
    supports_long_context=False,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
