"""olmoe-1b-7b [moe] — 64 experts, top-8.

16L d_model=2048 16H (kv=16, MHA) expert d_ff=1024 vocab=50304.
[arXiv:2409.02060; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=50_304,
    head_dim=128,
    mlp_variant="swiglu",
    tie_embeddings=False,
    num_experts=64,
    num_experts_per_token=8,
    moe_d_ff=1024,
    supports_long_context=False,
    source="arXiv:2409.02060; hf",
))
