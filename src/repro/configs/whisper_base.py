"""whisper-base [audio] — encoder-decoder, conv frontend stubbed.

6L d_model=512 8H (kv=8, i.e. MHA) d_ff=2048 vocab=51865.
[arXiv:2212.04356; unverified]  The conv1d audio frontend is a STUB:
input_specs() provides precomputed 1500-frame embeddings (30 s of audio at
50 Hz after the conv stride-2); the transformer backbone (6 encoder + 6
decoder layers with cross-attention) is fully implemented.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,            # decoder layers
    encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    head_dim=64,
    mlp_variant="gelu",
    tie_embeddings=True,
    supports_long_context=False,  # full attention
    source="arXiv:2212.04356; unverified",
))
