"""gemma-2b [dense] — GeGLU, head_dim=256, MQA.

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=256000.
[arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=256_000,
    head_dim=256,
    mlp_variant="geglu",
    tie_embeddings=True,
    supports_long_context=False,
    source="arXiv:2403.08295; hf",
))
