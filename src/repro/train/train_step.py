"""Train / prefill / decode step factories (jit + shardings).

``make_train_step`` builds the full pjit'd update: forward (remat'd scan),
softmax cross-entropy over the model-sharded vocab, backward, AdamW.
Microbatch gradient accumulation (``grad_accum``) trades collective volume
and activation memory against step latency — a first-class knob for the
perf iteration.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import sharding as SH
from repro.models import model as M
from repro.models.sharding_ctx import NO_SHARDING, ShardingCtx
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 vocab: Optional[int] = None) -> jnp.ndarray:
    """Mean next-token loss; stable, vocab may be model-sharded/padded."""
    logits = logits.astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        pad_mask = jnp.arange(logits.shape[-1]) >= vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_ctx(cfg, mesh, shape) -> ShardingCtx:
    if mesh is None:
        return NO_SHARDING
    return ShardingCtx(SH.activation_rules(cfg, mesh, shape), mesh)


def chunked_xent(cfg, params, hidden, labels, ctx,
                 chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy without materializing [B,S,V] fp32 logits.

    Scans sequence chunks; each chunk's unembed matmul + lse is a
    rematerialized step, so peak logits memory is B*chunk*V instead of
    B*S*V (EXPERIMENTS.md Section Perf, hypothesis P9).
    """
    B, S, E = hidden.shape
    ch = min(chunk, S)
    while S % ch:
        ch -= 1
    table = M.unembed_table(cfg, params)           # [Vp, E] fp32 master
    h_chunks = jnp.moveaxis(hidden.reshape(B, S // ch, ch, E), 1, 0)
    l_chunks = jnp.moveaxis(labels.reshape(B, S // ch, ch), 1, 0)

    def chunk_step(acc, xs):
        h_c, lab_c = xs
        logits = (h_c @ table.astype(h_c.dtype).T).astype(jnp.float32)
        logits = ctx.constrain(logits, "logits_bsv")
        if cfg.vocab_size < logits.shape[-1]:
            pad = jnp.arange(logits.shape[-1]) >= cfg.vocab_size
            logits = jnp.where(pad, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab_c[..., None],
                                   axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk_step),
                            jnp.float32(0), (h_chunks, l_chunks))
    return total / (B * S)


def make_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh=None, *,
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    remat: bool = True, grad_accum: int = 1,
                    chunked_loss: bool = False,
                    schedule_kwargs: Optional[Dict] = None):
    """Returns (step_fn, shardings) — step_fn(params, opt_state, batch, step)
    -> (params, opt_state, metrics)."""
    ctx = make_ctx(cfg, mesh, shape)
    sched = functools.partial(cosine_with_warmup, **(schedule_kwargs or {}))

    def loss_fn(params, batch):
        if chunked_loss:
            hidden = M.forward(cfg, params, batch, ctx=ctx, remat=remat,
                               return_pre_logits=True)
            return chunked_xent(cfg, params, hidden, batch["labels"], ctx)
        logits = M.forward(cfg, params, batch, ctx=ctx, remat=remat)
        return softmax_xent(logits, batch["labels"], cfg.vocab_size)

    def grads_for(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
            return (loss_acc + loss, grad_acc), None

        micro_batch = jax.tree.map(
            lambda a: a.reshape((grad_accum, a.shape[0] // grad_accum)
                                + a.shape[1:]), batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(
            micro, (jnp.float32(0), zeros), micro_batch)
        inv = 1.0 / grad_accum
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def step_fn(params, opt_state, batch, step):
        loss, grads = grads_for(params, batch)
        lr_scale = sched(step)
        params, opt_state, om = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, lr_scale)
        metrics = {"loss": loss, "lr_scale": lr_scale, **om}
        return params, opt_state, metrics

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1)), None

    params_shape = jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
    pspecs = SH.param_pspecs(cfg, params_shape, mesh)
    opt_specs = SH.opt_state_pspecs(pspecs)
    bspecs = SH.batch_pspecs(cfg, mesh, shape)
    shardings = {
        "params": SH.named(mesh, pspecs),
        "opt": SH.named(mesh, opt_specs),
        "batch": SH.named(mesh, bspecs),
    }
    metrics_spec = SH.named(
        mesh, {"loss": P(), "lr_scale": P(), "grad_norm": P()})
    fn = jax.jit(
        step_fn,
        in_shardings=(shardings["params"], shardings["opt"],
                      shardings["batch"], SH.named(mesh, P())),
        out_shardings=(shardings["params"], shardings["opt"], metrics_spec),
        donate_argnums=(0, 1),
    )
    return fn, shardings


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    """Inference prefill: forward only (no remat), logits out."""
    ctx = make_ctx(cfg, mesh, shape)

    def prefill(params, batch):
        return M.forward(cfg, params, batch, ctx=ctx, remat=False)

    if mesh is None:
        return jax.jit(prefill), None
    params_shape = jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
    pspecs = SH.param_pspecs(cfg, params_shape, mesh)
    bspecs = SH.batch_pspecs(cfg, mesh, shape)
    dp, _ = SH.dp_axes_for_batch(mesh, shape.global_batch)
    out_spec = P(dp if dp else None, None, "model")
    fn = jax.jit(prefill,
                 in_shardings=(SH.named(mesh, pspecs),
                               SH.named(mesh, bspecs)),
                 out_shardings=SH.named(mesh, out_spec))
    return fn, {"params": SH.named(mesh, pspecs),
                "batch": SH.named(mesh, bspecs)}


def make_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    """One-token decode step against a seq_len KV cache."""
    ctx = make_ctx(cfg, mesh, shape)

    def serve(params, cache, tokens, pos):
        extras = None
        if cfg.mrope:
            b = tokens.shape[0]
            extras = {"positions_3d": jnp.broadcast_to(
                pos, (3, b, 1)).astype(jnp.int32)}
        return M.decode_step(cfg, params, cache, tokens, pos, ctx=ctx,
                             batch_extras=extras)

    if mesh is None:
        return jax.jit(serve, donate_argnums=(1,)), None

    params_shape = jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
    cache_shape = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    pspecs = SH.param_pspecs(cfg, params_shape, mesh)
    cspecs = SH.cache_pspecs(cfg, mesh, shape, cache_shape)
    dp, _ = SH.dp_axes_for_batch(mesh, shape.global_batch)
    dp = dp if dp else None
    fn = jax.jit(
        serve,
        in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, cspecs),
                      SH.named(mesh, P(dp)), SH.named(mesh, P())),
        out_shardings=(SH.named(mesh, P(dp, "model")),
                       SH.named(mesh, cspecs)),
        donate_argnums=(1,),
    )
    return fn, {"params": SH.named(mesh, pspecs),
                "cache": SH.named(mesh, cspecs)}
