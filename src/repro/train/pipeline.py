"""Pipeline parallelism (GPipe-style) over a "stage" mesh axis.

The production dry-run mesh uses DP×TP(×pod) — every assigned cell fits
without pipelining — but a 1000+-node deployment of deeper models wants a
third parallel dimension.  This module provides it as a composable
transform: a stack of layer blocks is split into S contiguous stages,
stage s lives on mesh slice s of the "stage" axis, and microbatches stream
through with ``jax.lax.ppermute`` hops between neighbours.

Schedule: classic GPipe — T = n_micro + S − 1 ticks; tick t lets stage s
process microbatch t−s (bubble fraction (S−1)/T).  The whole schedule is a
``lax.scan``, so autodiff replays it in reverse and the backward pipeline
falls out for free; activations for the backward are held per tick
(activation-offload / 1F1B interleaving is the known follow-up and is out
of scope here).

``pipeline_apply`` is deliberately generic: ``block_fn(params, x) -> x``
is any per-stage computation (tests use transformer-ish MLP blocks).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(block_fn: Callable, stage_params, x_micro, *, mesh,
                   stage_axis: str = "stage"):
    """Run microbatches through pipeline stages.

    Args:
      block_fn: (params_for_stage, x [mb, d]) -> [mb, d].
      stage_params: pytree with leading dim S (one slice per stage).
      x_micro: [n_micro, mb, d] microbatch stream (replicated input).
      mesh: mesh containing ``stage_axis`` of size S.
    Returns [n_micro, mb, d] outputs (from the last stage, replicated).
    """
    S = mesh.shape[stage_axis]
    n_micro, mb, d = x_micro.shape
    T = n_micro + S - 1

    def shard_fn(params_local, xs):
        # params_local: stage's slice (leading dim 1); xs: full stream.
        params_local = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            buf, outputs = carry
            # Stage 0 ingests microbatch t (when in range); others take
            # the neighbour's output from the previous tick.
            incoming = jax.lax.ppermute(buf, stage_axis, perm)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            feed = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                keepdims=False)
            x_in = jnp.where(sid == 0, feed, incoming)
            y = block_fn(params_local, x_in)
            # Last stage commits microbatch t-S+1 at tick t.
            out_idx = jnp.clip(t - (S - 1), 0, n_micro - 1)
            valid = (t - (S - 1) >= 0) & (sid == S - 1)
            committed = jnp.where(valid, y, 0.0)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                             keepdims=False) + committed,
                out_idx, 0)
            return (y, outputs), None

        init = (jnp.zeros((mb, d), x_micro.dtype),
                jnp.zeros_like(xs))
        (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(T))
        # Only the last stage holds real outputs; broadcast to all stages.
        outputs = jax.lax.psum(
            jnp.where(sid == S - 1, outputs, 0.0), stage_axis)
        return outputs

    return jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)


def split_stages(stacked_params, num_stages: int):
    """Reshape a [L, ...] layer-stacked pytree to [S, L/S, ...]."""
    def re(a):
        L = a.shape[0]
        assert L % num_stages == 0, (L, num_stages)
        return a.reshape((num_stages, L // num_stages) + a.shape[1:])
    return jax.tree.map(re, stacked_params)
