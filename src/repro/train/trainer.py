"""Fault-tolerant training loop.

Features exercised by the integration tests:
  * checkpoint/restart: auto-resume from the newest committed checkpoint;
    the stateless data pipeline guarantees no sample is replayed/skipped.
  * crash safety: checkpoints are atomic (tmp + rename + sentinel); a kill
    mid-save leaves the previous checkpoint authoritative.
  * elastic restart: checkpoints are topology-free; a restart may pass a
    different mesh and the restore path reshard-loads.
  * straggler watchdog: EMA of step wall-time; steps slower than
    ``straggler_factor`` x EMA are logged and counted (on real fleets this
    feeds the controller that cordons slow hosts; here it is observable
    state the tests assert on).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import model as M
from repro.optim import adamw
from repro.train import train_step as TS

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    ema_decay: float = 0.9
    grad_accum: int = 1
    seed: int = 0
    schedule_kwargs: Optional[Dict] = None


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 tcfg: TrainerConfig, mesh=None,
                 opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.tcfg = tcfg
        self.mesh = mesh
        self.opt_cfg = opt_cfg
        self.pipeline = Pipeline(cfg, shape, data_cfg)
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep)
        self.step_fn, self.shardings = TS.make_train_step(
            cfg, shape, mesh, opt_cfg=opt_cfg,
            grad_accum=tcfg.grad_accum,
            schedule_kwargs=tcfg.schedule_kwargs)
        self.params = None
        self.opt_state = None
        self.start_step = 0
        self.step_time_ema: Optional[float] = None
        self.straggler_events = []
        self.history = []

    # ------------------------------------------------------------------
    def init_or_restore(self) -> int:
        latest = self.ckpt.latest_step()
        if latest is not None:
            shardings = None
            if self.shardings is not None:
                shardings = {"params": self.shardings["params"],
                             "opt": self.shardings["opt"]}
            state = self.ckpt.restore(latest, shardings=shardings)
            self.params = state["params"]
            self.opt_state = state["opt"]
            self.start_step = latest + 1
            log.info("resumed from step %d", latest)
            return self.start_step
        key = jax.random.PRNGKey(self.tcfg.seed)
        self.params = M.init_params(self.cfg, key)
        self.opt_state = adamw.init_state(self.params, self.opt_cfg)
        if self.shardings is not None:
            self.params = jax.device_put(self.params,
                                         self.shardings["params"])
            self.opt_state = jax.device_put(self.opt_state,
                                            self.shardings["opt"])
        self.start_step = 0
        return 0

    # ------------------------------------------------------------------
    def _put_batch(self, batch: Dict):
        if self.shardings is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda a, s: jax.device_put(a, s), batch,
            {k: self.shardings["batch"][k] for k in batch})

    def _watchdog(self, step: int, dt: float):
        if self.step_time_ema is None:
            self.step_time_ema = dt
            return
        if dt > self.tcfg.straggler_factor * self.step_time_ema:
            self.straggler_events.append((step, dt, self.step_time_ema))
            log.warning("straggler step %d: %.3fs vs EMA %.3fs",
                        step, dt, self.step_time_ema)
        d = self.tcfg.ema_decay
        self.step_time_ema = d * self.step_time_ema + (1 - d) * dt

    # ------------------------------------------------------------------
    def run(self, num_steps: int, stop_after: Optional[int] = None) -> Dict:
        """Run to ``num_steps`` total; ``stop_after`` simulates preemption
        after that many *local* steps (tests use it to exercise restart)."""
        if self.params is None:
            self.init_or_restore()
        done = 0
        metrics = {}
        for step in range(self.start_step, num_steps):
            batch = self._put_batch(self.pipeline.batch_for_step(step))
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch,
                jax.numpy.asarray(step))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self._watchdog(step, dt)
            self.history.append({"step": step, "loss": loss, "dt": dt})
            if (step + 1) % self.tcfg.ckpt_every == 0 or \
                    step == num_steps - 1:
                self.ckpt.save(step, {"params": self.params,
                                      "opt": self.opt_state})
            done += 1
            if stop_after is not None and done >= stop_after:
                # Preemption path: real fleets checkpoint on SIGTERM.
                if self.ckpt.latest_step() != step:
                    self.ckpt.save(step, {"params": self.params,
                                          "opt": self.opt_state})
                break
        return {k: float(v) for k, v in metrics.items()} if metrics else {}
