"""repro.train"""
