"""Batched/streamed SpMM: plan once, execute across many right-hand sides.

The dispatcher (``repro.sparse.dispatch``) already splits SpMM into a plan
phase (classify the structure, evaluate each format's sparsity-aware
roofline, amortize conversion cost over an expected reuse count) and an
execute phase (convert once, run the chosen kernel).  This module is the
serving-path API on top of that split:

    spec = BSpec(d=64, reuse=256)        # 256 RHS batches expected
    plan = sparse.plan(m, spec)          # classify + model + convert ONCE
    c0 = plan.execute(b0)                # zero-dispatch replay
    cs = plan.execute_many(bs)           # a stream of [n, d] batches
    cw = plan.execute_wide(b_wide)       # one [n, D] B, column-sharded

Two things distinguish this from calling ``sparse.spmm`` per batch:

1. **Amortized planning.**  The expected reuse count in the ``BSpec`` is
   fed into the DispatchPlan's conversion-cost model, so the chosen format
   can differ from the single-shot choice: a format that is faster per
   call but expensive to build (BCSR's dense t x t blocks) loses at
   ``reuse=1`` and wins at ``reuse=1000`` (the paper's conversion-cost
   amortization term, Section III).

2. **Zero-dispatch replay.**  ``execute`` holds the bound kernel closure
   from ``Dispatcher.executor`` — no classification, no plan-cache or
   conversion-cache lookups, no policy checks per call.  Per-call dispatch
   pays those on every batch; the streamed benchmark
   (``benchmarks/stream.py``) measures the gap across the four paper
   sparsity structures.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Iterable, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core.patterns import COOMatrix
from repro.sparse import dispatch as _dispatch

_LOG = logging.getLogger(__name__)

#: ``execute_many`` warns when realized reuse exceeds the planned horizon
#: by more than this factor (the conversion-amortization model was fed a
#: horizon off by >2x, so the format choice may be stale).
REUSE_DRIFT_FACTOR = 2.0


@dataclasses.dataclass(frozen=True)
class BSpec:
    """Static description of the dense right-hand-side stream.

    Attributes:
        d: width of each right-hand side (every ``B`` is ``[n, d]``).
        reuse: expected number of executions the plan will serve.  This is
            the conversion amortization horizon fed to the dispatcher's
            cost model; under-estimating it biases the choice toward
            cheap-to-build formats, over-estimating toward
            fast-steady-state ones.
        dtype: element dtype of the stream (informational; kernels follow
            the dtype of each ``B`` actually passed).
        precision: optional storage precision to force on the plan — a
            :class:`repro.core.precision.Precision` or a token like
            ``"bf16"`` / ``"bf16i32"``.  ``None`` (default) lets the
            dispatcher pick per the roofline and the accuracy gate.
        tolerance: elementwise accuracy budget handed to the dispatcher's
            precision gate; reduced-precision candidates become eligible
            only when ``tolerance`` covers their rounding eps (see
            ``Dispatcher.plan``).  ``None`` uses the dispatcher default.
    """

    d: int
    reuse: int = 32
    dtype: Any = jnp.float32
    precision: Any = None
    tolerance: Optional[float] = None

    def __post_init__(self):
        """Validate widths and horizons at construction time."""
        if self.d < 1:
            raise ValueError(f"BSpec.d must be >= 1, got {self.d}")
        if self.reuse < 1:
            raise ValueError(f"BSpec.reuse must be >= 1, got {self.reuse}")


def as_b_spec(spec: Union[int, BSpec, jnp.ndarray],
              *, reuse: Optional[int] = None) -> BSpec:
    """Coerce a width, an example batch, or a BSpec into a ``BSpec``.

    Args:
        spec: an ``int`` width ``d``, an example ``[n, d]`` array, or an
            existing :class:`BSpec` (returned as-is unless ``reuse`` is
            given).
        reuse: optional override for the expected execution count.

    Returns:
        A normalized :class:`BSpec`.
    """
    if isinstance(spec, BSpec):
        return spec if reuse is None else dataclasses.replace(
            spec, reuse=reuse)
    if isinstance(spec, (int, np.integer)):
        return BSpec(d=int(spec), reuse=32 if reuse is None else reuse)
    shape = getattr(spec, "shape", None)
    if shape is not None and len(shape) == 2:
        return BSpec(d=int(shape[1]), reuse=32 if reuse is None else reuse,
                     dtype=getattr(spec, "dtype", jnp.float32))
    raise TypeError(
        f"b_spec must be an int width, a BSpec, or an example [n, d] "
        f"array; got {type(spec).__name__}")


class StreamPlan:
    """A persistent, replayable SpMM plan for one matrix and a RHS stream.

    Construction runs the whole one-time pipeline — structure
    classification, per-format roofline evaluation with the stream's reuse
    horizon, format conversion, and kernel layout packing — so every
    ``execute`` afterwards is a bare kernel launch.  Instances are
    intended to live as long as the serving process holds the matrix.
    """

    def __init__(self, dispatcher: _dispatch.Dispatcher, m: COOMatrix,
                 spec: BSpec, *, strategy: str = "auto"):
        """Plan and bind; see :func:`plan` for the usual entry point.

        Args:
            dispatcher: the :class:`repro.sparse.dispatch.Dispatcher` that
                owns caches and hardware model.
            m: square sparse pattern, ``[n, n]``.
            spec: the stream description (width + expected reuse).
            strategy: ``"auto"`` or a forced format name.
        """
        self._m = m
        self._dispatcher = dispatcher
        self._strategy = strategy
        self.spec = spec
        self.dispatch = dispatcher.plan(m, spec.d, strategy=strategy,
                                        reuse=spec.reuse,
                                        precision=spec.precision,
                                        tolerance=spec.tolerance)
        # Eager bind: conversion + packing happen NOW, not on first
        # execute.  (The first execute still pays the kernel's one-time
        # XLA compile for this shape — latency-sensitive servers should
        # warm up with one batch, as launch/serve.py does.)
        self._run = self._bind()
        self.executed = 0
        self._reuse_warned = False

    def _bind(self):
        """Resolve the executor this plan replays.

        Subclasses override this hook to bind a different execution
        tier over the same DispatchPlan — ``repro.sparse.shard``'s
        :class:`~repro.sparse.shard.ShardedPlan` returns a ``shard_map``
        closure here instead of the single-device kernel.
        """
        return self._dispatcher.executor(self._m, self.dispatch)

    @property
    def n(self) -> int:
        """Matrix dimension; every RHS must have ``n`` rows."""
        return self._m.n

    @property
    def chosen(self) -> str:
        """The format the amortized roofline model selected."""
        return self.dispatch.chosen

    @property
    def precision(self) -> str:
        """The storage-precision token the plan executes at (e.g.
        ``"f32i32"`` or ``"bf16i16"``); replays pack values and indices
        at these dtypes and accumulate in fp32."""
        return self.dispatch.precision

    def _check(self, b: jnp.ndarray, *, width: Optional[int] = None) -> None:
        """Reject shape-mismatched operands with a precise message."""
        if b.ndim != 2 or b.shape[0] != self.n:
            raise ValueError(
                f"operand shape {tuple(b.shape)} incompatible with plan for "
                f"[{self.n}, {self.n}] matrix; expected [{self.n}, d]")
        if width is not None and b.shape[1] != width:
            raise ValueError(
                f"operand width {b.shape[1]} != planned width {width}; "
                f"use execute_wide for other widths")

    def execute(self, b: jnp.ndarray) -> jnp.ndarray:
        """Run ``C = A @ B`` for one planned-width batch.

        Args:
            b: dense right-hand side, ``[n, spec.d]``.

        Returns:
            ``C`` as a dense ``[n, spec.d]`` array.
        """
        self._check(b, width=self.spec.d)
        out = self._run(b)
        self.executed += 1          # count only replays that succeeded
        self._audit_reuse()
        return out

    def execute_async(self, b: jnp.ndarray) -> jnp.ndarray:
        """Dispatch one planned-width batch without a sync point.

        Identical to :meth:`execute` except the caller owns the sync:
        the returned array is an in-flight device value (XLA dispatches
        asynchronously; see ``KernelSpec.async_dispatch``), so the host
        is free to stage the next operand while the device computes —
        the overlap the serving engine (``repro.sparse.engine``) builds
        its double buffering on.  Materialize with
        ``jax.block_until_ready``.

        Args:
            b: dense right-hand side, ``[n, spec.d]``.

        Returns:
            ``C`` as an un-materialized ``[n, spec.d]`` device array.
        """
        self._check(b, width=self.spec.d)
        out = self._run(b)
        self.executed += 1
        self._audit_reuse()
        return out

    def execute_many_async(self, bs: Union[jnp.ndarray, Sequence[jnp.ndarray],
                                           Iterable[jnp.ndarray]]) -> list:
        """Dispatch a whole stream with no sync point and no stacking.

        The async counterpart of :meth:`execute_many` (ROADMAP's async
        ``execute_many``): every batch is enqueued back-to-back so the
        device pipeline stays full, and the un-materialized per-batch
        results come back as a list — no ``jnp.stack`` barrier forcing a
        layout copy before the caller even needs the values.

        Args:
            bs: a stacked ``[k, n, d]`` array or an iterable of ``k``
                arrays of shape ``[n, d]``.

        Returns:
            List of ``k`` in-flight ``[n, d]`` device arrays; call
            ``jax.block_until_ready`` on them (or on the list) to wait.
        """
        if hasattr(bs, "ndim") and getattr(bs, "ndim", 0) == 3:
            bs = [bs[i] for i in range(bs.shape[0])]
        outs = []
        for b in bs:
            self._check(b, width=self.spec.d)
            outs.append(self._run(b))
            self.executed += 1
        self._audit_reuse()
        return outs

    def execute_many(self, bs: Union[jnp.ndarray, Sequence[jnp.ndarray],
                                     Iterable[jnp.ndarray]]) -> jnp.ndarray:
        """Replay the bound kernel across a stream of right-hand sides.

        Args:
            bs: either a stacked ``[k, n, d]`` array or an iterable of
                ``k`` arrays of shape ``[n, d]``.

        Returns:
            The stacked results, ``[k, n, d]``.  Result dtype follows the
            operands, except an empty stream, which has no operands to
            follow and returns a ``[0, n, d]`` array of ``spec.dtype``.
        """
        if hasattr(bs, "ndim") and getattr(bs, "ndim", 0) == 3:
            bs = [bs[i] for i in range(bs.shape[0])]
        outs = []
        for b in bs:
            self._check(b, width=self.spec.d)
            outs.append(self._run(b))
            self.executed += 1
        self._audit_reuse()
        if not outs:
            return jnp.zeros((0, self.n, self.spec.d), dtype=self.spec.dtype)
        return jnp.stack(outs)

    def _audit_reuse(self) -> None:
        """Warn (once) when the realized reuse drifts >2x past the plan.

        The reuse horizon is an input to the conversion-amortization
        model; when the stream outlives it by more than
        ``REUSE_DRIFT_FACTOR``, the format choice may no longer be the
        amortized-best one — :meth:`replan` re-evaluates at the observed
        horizon (ROADMAP streamed-dispatch follow-up, minimal version).
        """
        if self._reuse_warned:
            return
        if self.executed > REUSE_DRIFT_FACTOR * self.spec.reuse:
            self._reuse_warned = True
            _LOG.warning(
                "StreamPlan reuse horizon off by >%.0fx: planned %d, "
                "executed %d (utilization %.1fx); the conversion "
                "amortization that picked %r assumed the shorter stream — "
                "consider plan.replan(observed_reuse=%d)",
                REUSE_DRIFT_FACTOR, self.spec.reuse, self.executed,
                self.executed / self.spec.reuse, self.chosen, self.executed)

    def replan(self, observed_reuse: int) -> "StreamPlan":
        """Re-plan at an observed reuse horizon; returns a new StreamPlan.

        Runs the dispatcher's amortized roofline again with
        ``reuse=observed_reuse`` — the chosen format can flip (e.g. to an
        expensive-to-build but faster-steady-state one once the horizon
        justifies its conversion).  Cheap when the format does not change:
        the dispatcher's conversion and layout caches are already warm for
        this matrix.

        Args:
            observed_reuse: the realized (or newly expected) number of
                executions, e.g. ``plan.executed``.

        Returns:
            A fresh bound :class:`StreamPlan`; this plan stays valid.
        """
        if observed_reuse < 1:
            raise ValueError(
                f"observed_reuse must be >= 1, got {observed_reuse}")
        spec = dataclasses.replace(self.spec, reuse=observed_reuse)
        return StreamPlan(self._dispatcher, self._m, spec,
                          strategy=self._strategy)

    def maybe_replan(self) -> Optional["StreamPlan"]:
        """The mid-stream re-plan hook: a fresh plan when the audit fired.

        Returns ``None`` while the planned horizon still holds.  Once the
        realized reuse drifts past ``REUSE_DRIFT_FACTOR`` (the same
        condition that flips ``stats()["replan_suggested"]``), returns
        :meth:`replan` at the observed horizon — a fully bound plan whose
        format choice reflects the stream actually being served.  The
        caller swaps atomically (both plans stay valid; the serving
        engine does this between micro-batches, never mid-batch).
        """
        if not self._reuse_warned:
            return None
        return self.replan(max(self.executed, 1))

    def exec_hints(self) -> dict:
        """Execution metadata for the serving engine's staging policy.

        Resolved from the bound :class:`repro.kernels.registry.KernelSpec`:
        ``async_dispatch`` (the launch enqueues and returns, so staging
        the next micro-batch overlaps device compute) and ``donate_b``
        (the launch may alias B's buffer, so the staged operand is
        consumed at dispatch).  See the field docs on ``KernelSpec``.
        """
        from repro.kernels import registry
        spec = registry.get(self.dispatch.chosen, self.dispatch.backend)
        return {"async_dispatch": spec.async_dispatch,
                "donate_b": spec.donate_b}

    def coalesce_block_d(self, total_cols: int) -> int:
        """Widest per-launch column block a coalesced batch may replay at.

        jax-backend kernels adapt their operand width per call and carry
        no resident-VMEM model, so a whole coalesced micro-batch can run
        as one launch — the engine's throughput win.  The width is
        *quantized* to a power-of-two multiple of the planned ``spec.d``
        rather than the raw column count: every distinct launch width
        jit-compiles its own program, and un-quantized micro-batches
        (whose widths vary with arrival timing) would recompile on
        nearly every batch — ~200 ms a time, swamping the coalescing
        win.  Size classes keep the compiled-shape set logarithmic, and
        the engine's warm-up primes them.  Pallas layouts were packed
        for the planned width (``resolve_b_tile``'s per-d B-slab
        re-packing sized the VMEM slab for ``plan_d = spec.d``), so
        their replay stays at planned-width blocks: a wider launch would
        burst the slab budget the layout was built against.

        Args:
            total_cols: the coalesced batch's total column count.

        Returns:
            The ``block_d`` to pass to :meth:`execute_wide` (the engine
            pads the batch to a multiple of it, keeping launch shapes
            from proliferating).
        """
        if self.dispatch.backend == "jax":
            d = max(self.spec.d, 1)
            blocks = -(-max(int(total_cols), 1) // d)   # ceil-div
            size = 1
            while size < blocks:
                size *= 2
            return size * d
        return self.spec.d

    def execute_wide(self, b: jnp.ndarray,
                     *, block_d: Optional[int] = None) -> jnp.ndarray:
        """Column-shard one wide ``B`` through the plan.

        A ``[n, D]`` operand with ``D`` much larger than the planned width
        is split into column blocks of ``block_d`` (default: the planned
        ``spec.d``), each block executed through the bound kernel, and the
        results concatenated — the sharded-serving shape where one model's
        activation matrix is wider than the per-request batch the plan was
        tuned for.

        Args:
            b: dense right-hand side, ``[n, D]``.
            block_d: column block width; defaults to ``spec.d``.

        Returns:
            ``C`` as a dense ``[n, D]`` array.
        """
        self._check(b)
        block_d = self.spec.d if block_d is None else int(block_d)
        if block_d < 1:
            raise ValueError(f"block_d must be >= 1, got {block_d}")
        total = b.shape[1]
        if total == 0:
            return jnp.zeros((self.n, 0), dtype=b.dtype)
        outs = []
        for lo in range(0, total, block_d):
            outs.append(self._run(b[:, lo:lo + block_d]))
            self.executed += 1
        self._audit_reuse()
        return jnp.concatenate(outs, axis=1)

    def reset_stats(self) -> None:
        """Zero the execution counter (e.g. after warm-up calls, so
        :meth:`stats` reflects served requests only)."""
        self.executed = 0

    def stats(self) -> dict:
        """Amortization audit: planned horizon vs realized executions.

        Returns:
            Dict with ``chosen``, ``regime``, ``backend``, ``precision``
            (the storage-dtype token replays run at), ``planned_reuse``,
            ``executed``, ``reuse_utilization`` (executed / planned —
            below 1.0 means the conversion cost was amortized over fewer
            calls than the model assumed), and ``replan_suggested`` (the
            horizon drifted past ``REUSE_DRIFT_FACTOR``; see
            :meth:`replan`).
        """
        return {
            "chosen": self.dispatch.chosen,
            "regime": self.dispatch.regime,
            "backend": self.dispatch.backend,
            "precision": self.dispatch.precision,
            "planned_reuse": self.spec.reuse,
            "executed": self.executed,
            "reuse_utilization": self.executed / self.spec.reuse,
            "replan_suggested": self._reuse_warned,
        }


def plan(m: COOMatrix, b_spec: Union[int, BSpec, jnp.ndarray], *,
         strategy: str = "auto", reuse: Optional[int] = None,
         precision=None, tolerance: Optional[float] = None,
         mesh=None, b_strategy: str = "auto",
         dispatcher: Optional[_dispatch.Dispatcher] = None) -> StreamPlan:
    """Plan once for a stream of right-hand sides; the serving entry point.

    Args:
        m: square sparse pattern (``repro.core.patterns.COOMatrix``), [n, n].
        b_spec: the stream description — an ``int`` width, a
            :class:`BSpec`, or an example ``[n, d]`` batch.
        strategy: ``"auto"`` or a format name to force.
        reuse: shorthand override for ``BSpec.reuse`` (expected number of
            executions).
        precision: shorthand override for ``BSpec.precision`` — force the
            plan onto one storage precision (``"bf16"``, ``"bf16i32"``, a
            :class:`~repro.core.precision.Precision`).
        tolerance: shorthand override for ``BSpec.tolerance`` — the
            accuracy budget that lets the dispatcher consider
            reduced-precision candidates on its own.
        mesh: optional device mesh (e.g. from ``repro.launch.mesh``).
            When given, returns a :class:`repro.sparse.shard.ShardedPlan`
            that partitions the matrix across the mesh and executes under
            ``shard_map``.
        b_strategy: sharded-tier B-distribution strategy (``"auto"`` or
            one of ``repro.sparse.shard.B_STRATEGIES``); only meaningful
            with ``mesh``.
        dispatcher: dispatcher to plan on; defaults to the module-level one
            shared with ``sparse.spmm``.

    Returns:
        A bound :class:`StreamPlan` (or ``ShardedPlan`` when ``mesh`` is
        given); call ``execute`` / ``execute_many`` / ``execute_wide``.
    """
    spec = as_b_spec(b_spec, reuse=reuse)
    if precision is not None or tolerance is not None:
        spec = dataclasses.replace(
            spec,
            precision=spec.precision if precision is None else precision,
            tolerance=spec.tolerance if tolerance is None else tolerance)
    disp = dispatcher or _dispatch.default_dispatcher()
    if mesh is not None:
        from repro.sparse.shard import ShardedPlan
        return ShardedPlan(disp, m, spec, mesh, strategy=strategy,
                           b_strategy=b_strategy)
    if b_strategy != "auto":
        raise ValueError("b_strategy requires a mesh (sharded tier)")
    return StreamPlan(disp, m, spec, strategy=strategy)
