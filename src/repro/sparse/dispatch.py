"""Structure-aware SpMM dispatch: the paper's thesis as runtime architecture.

The paper's core claim is that no single roofline model predicts SpMM
across sparsity structures — the right storage format (and kernel) must be
chosen per matrix structure.  This module turns that claim into the
system's dispatch layer:

    plan = plan_spmm(m, d)            # inspectable decision record
    c = spmm(m, b, strategy="auto")   # classify -> model -> convert -> run

For each candidate format (CSR / ELL / BCSR / DIA) the dispatcher

  1. applies the *applicability policy* (the SpChar-style structural gates
     that previously lived as ad-hoc heuristics in benchmarks/spmm_suite.py),
     emitting a skip reason when a format is rejected;
  2. evaluates the candidate's sparsity-aware arithmetic intensity on the
     active HardwareSpec: B-traffic from the detected structural regime
     (Section III models), A-traffic from the format's actual storage;
  3. caps the bandwidth roofline ``beta * AI`` with a format compute
     ceiling ``peak * efficiency * useful_fraction`` — dense-padded formats
     (ELL padding, BCSR's t x t blocks, DIA's in-band zeros) issue more
     FLOPs than the 2*d*nnz useful ones, and on gather-bound hosts the
     implementation efficiency, not DRAM, is the binding resource (the
     refuted-claims discussion in the benchmark suite);
  4. amortizes the one-time format conversion cost over an expected reuse
     count, so a format that is 10% faster per call but costs 50 calls to
     build loses at reuse=8 and wins at reuse=1000.

The winning ``(format, kernel)`` pair is returned as a cached
``DispatchPlan``; ``spmm`` executes it with per-matrix conversion caching,
selecting the pure-JAX or the Pallas kernel path per ``backend``.

Conversion-cost caveat: conversion time is modeled as streaming the built
format at ``beta`` (read + write); the host-side converters are not that
fast, so treat amortized numbers as a lower bound on the break-even reuse.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import CalibrationStore
from repro.core.classify import StructureReport, block_stats, classify
from repro.core.precision import (DEFAULT_PRECISION, INT16_MAX_EXTENT,
                                  PRECISIONS, Precision, as_precision)
from repro.data.dtree import (DecisionTree, DispatchTreeStore,
                              features_from_report)
from repro.core.hardware import HOST_CPU, TPU_V5E, HardwareSpec
from repro.core.roofline import ComputeCeiling
from repro.core import sparsity_models as sm
from repro.core.patterns import COOMatrix
from repro.sparse import formats as fmt

FORMATS: Tuple[str, ...] = ("csr", "ell", "bcsr", "dia",
                            "binned", "rowsplit", "ell_coo")
STRATEGIES: Tuple[str, ...] = ("auto",) + FORMATS

#: Per-format compute ceiling: ``(peak_fraction, d_half)``.  Each
#: implementation sustains ``peak * peak_fraction * d / (d + d_half)`` on
#: its *issued* FLOPs (padding included): per-nonzero index/bookkeeping
#: work is amortized over the d dense columns, so throughput saturates
#: with growing d at a format-specific rate — CSR's scalar segment-sum has
#: the largest per-nonzero overhead (d_half ~ 100), DIA's streaming axpy
#: almost none (d_half ~ 3).  These are the *fallback* constants, once
#: measured on one reference container; ``repro.core.calibrate`` fits
#: host-specific replacements and the dispatcher prefers a persisted
#: calibration whenever one matches the active HardwareSpec fingerprint
#: (each candidate records its provenance in ``ceiling_source``).
#: Override per dispatcher via ``Dispatcher(efficiency=...)``.
DEFAULT_EFFICIENCY: Dict[str, Tuple[float, float]] = {
    "csr": (0.030, 112.0),
    "ell": (0.040, 8.0),
    "bcsr": (0.600, 28.0),
    "dia": (0.057, 3.0),
    # Scale-free-regime kernels (PR 8).  On compute-bound hosts these sit
    # strictly below CSR (same gather/segment-sum algebra plus binning /
    # window bookkeeping), so they only win where their *bandwidth* model
    # does — i.e. on bandwidth-bound parts where slab binning collapses
    # the B-traffic term.  Calibration replaces these like any other.
    "binned": (0.022, 112.0),
    "rowsplit": (0.027, 104.0),
    # ell_coo's jax path is an ELL body scan *plus* a COO-tail
    # segment-sum; the tail pass inherits CSR's gather d-scaling, so the
    # blended d_half sits between ELL's 8 and CSR's 112.  (With ELL's
    # d_half=8 it over-predicted small-d launches on *blocked* matrices
    # and beat BCSR on FEM suites it measures 2x slower on.)
    "ell_coo": (0.036, 40.0),
}


@dataclasses.dataclass(frozen=True)
class CandidateEval:
    """One (format, precision) audit record inside a DispatchPlan."""

    format: str
    eligible: bool
    skip_reason: Optional[str]        # None when eligible
    ai: Optional[float]               # sparsity-aware arithmetic intensity
    useful_fraction: Optional[float]  # useful FLOPs / issued FLOPs
    predicted_gflops: Optional[float]     # steady-state (no conversion)
    amortized_gflops: Optional[float]     # incl. conversion / reuse
    conversion_bytes: Optional[float]
    params: dict = dataclasses.field(default_factory=dict)
    #: Compute-ceiling provenance: "default" | "calibrated" | "override".
    ceiling_source: str = "default"
    #: Storage precision token this row was modeled at
    #: (``repro.core.precision.Precision.token``): "f32i32" | "bf16i32" |
    #: "bf16i16".  Reduced-precision rows gated out by the caller's
    #: ``tolerance`` (or an int16-illegal extent) keep their predictions
    #: for audit but carry ``eligible=False`` and the gate's
    #: ``skip_reason``.
    precision: str = "f32i32"


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """The dispatcher's full, inspectable decision for one (matrix, d)."""

    chosen: str                       # winning format
    strategy: str                     # "auto" or the forced format
    regime: str                       # detected sparsity regime
    d: int
    reuse: int                        # conversion amortization horizon
    backend: str                      # "jax" | "pallas"
    hardware: str                     # HardwareSpec.name used for prediction
    candidates: Tuple[CandidateEval, ...]
    #: Winning storage precision (token): the layouts are packed and the
    #: kernel launched at these value/index dtypes.
    precision: str = "f32i32"
    #: The relative error budget the accuracy gate ran with; reduced
    #: value dtypes were eligible only where ``tolerance >= dtype eps``.
    tolerance: float = 0.0
    #: Staleness warning from the CalibrationStore (fingerprint mismatch
    #: or a calibration predating the kernel registry version); None when
    #: the store is silent.  Rendered by :meth:`summary`.
    calibration_note: Optional[str] = None
    #: Who made the final call: ``"analytic"`` (the roofline ranking) or
    #: ``"tree"`` (the fitted dispatch tree, consulted because the
    #: analytic top two were within ``tree_margin`` of each other).
    #: Provenance, exactly like ``ceiling_source`` for ceilings.
    decision_source: str = "analytic"
    #: The tree's split trail (``feature<=thr`` ... ``leaf:fmt(n=..)``)
    #: when ``decision_source == "tree"``; empty otherwise.
    decision_path: Tuple[str, ...] = ()

    @property
    def skips(self) -> Dict[str, str]:
        """format -> reason, for every policy-rejected candidate.

        Keyed off the baseline fp32 rows (every format has one and the
        baseline is never precision-gated), so the reasons here are
        exactly the structural policy reasons; precision-gate rejections
        live in :attr:`precision_skips`.
        """
        return {c.format: c.skip_reason for c in self.candidates
                if not c.eligible and c.precision == "f32i32"}

    @property
    def precision_skips(self) -> Dict[Tuple[str, str], str]:
        """(format, precision) -> reason for precision-gated rows.

        Only rows whose *precision* was rejected (tolerance too tight for
        bf16, int16 extent overflow) appear; rows skipped for structural
        policy are in :attr:`skips`.
        """
        return {(c.format, c.precision): c.skip_reason
                for c in self.candidates
                if not c.eligible and c.precision != "f32i32"
                and c.format not in self.skips}

    @property
    def ceiling_sources(self) -> Dict[str, str]:
        """format -> compute-ceiling provenance (default/calibrated/override)."""
        return {c.format: c.ceiling_source for c in self.candidates}

    def candidate(self, name: str,
                  precision: Optional[str] = None) -> CandidateEval:
        """Return the :class:`CandidateEval` for format ``name``.

        Args:
            name: one of ``FORMATS`` (``"csr" | "ell" | "bcsr" | "dia" |
                "binned" | "rowsplit" | "ell_coo"``).
            precision: a precision token ("f32i32", "bf16i32", "bf16i16")
                to pick that exact row.  ``None`` returns the row the
                plan actually ranked for this format: the chosen row when
                ``name`` won, else the best eligible row, else the fp32
                baseline.

        Returns:
            The audit record for that (format, precision).

        Raises:
            KeyError: if the pair was not evaluated in this plan.
        """
        if precision is not None:
            token = as_precision(precision).token
            for c in self.candidates:
                if c.format == name and c.precision == token:
                    return c
            raise KeyError((name, token))
        if name == self.chosen:
            return self.candidate(name, self.precision)
        rows = [c for c in self.candidates if c.format == name]
        if not rows:
            raise KeyError(name)
        eligible = [c for c in rows if c.eligible]
        if eligible:
            return max(eligible, key=lambda c: c.amortized_gflops or 0.0)
        return next(c for c in rows if c.precision == "f32i32")

    def summary(self) -> str:
        """Render the decision as a human-readable multi-line table."""
        lines = [f"DispatchPlan(regime={self.regime}, d={self.d}, "
                 f"backend={self.backend}, hw={self.hardware}, "
                 f"reuse={self.reuse}, tol={self.tolerance:.1e}, "
                 f"decision={self.decision_source})"
                 f" -> {self.chosen} @ {self.precision}"]
        for c in self.candidates:
            mark = "*" if (c.format == self.chosen
                           and c.precision == self.precision) else " "
            if c.predicted_gflops is not None:
                perf = (f"AI={c.ai:6.3f}  pred={c.predicted_gflops:7.2f}"
                        f"  amort={c.amortized_gflops:7.2f} GF/s"
                        f" [{c.ceiling_source}]")
            else:
                perf = "(not modeled)"
            tail = "" if c.eligible else f"  SKIP: {c.skip_reason}"
            lines.append(f" {mark} {c.format:8s} {c.precision:7s} "
                         f"{perf}{tail}")
        if self.decision_path:
            lines.append(" ~ tree: " + " -> ".join(self.decision_path))
        if self.calibration_note:
            lines.append(f" ! {self.calibration_note}")
        return "\n".join(lines)


def _degree_stats(m: COOMatrix) -> Tuple[float, int]:
    deg = np.bincount(m.rows, minlength=m.n)
    return float(deg.mean()), int(deg.max())


def _num_diagonals(m: COOMatrix) -> int:
    return int(np.unique(m.cols.astype(np.int64) - m.rows).shape[0])


def _evict_cb(dispatcher_ref: "weakref.ref", key: int) -> None:
    """Finalizer body: must not hold the Dispatcher alive (weakref only),
    or every tracked matrix would pin the dispatcher's caches."""
    disp = dispatcher_ref()
    if disp is not None:
        disp._evict(key)


class Dispatcher:
    """Plans, caches, and executes structure-aware SpMM.

    One instance owns two caches keyed by matrix identity (entries are
    evicted when the COOMatrix is garbage collected):

      * plan cache:        (matrix, d, strategy, knobs) -> DispatchPlan
      * conversion cache:  (matrix, format, t)          -> format container
    """

    def __init__(self, hardware: Optional[HardwareSpec] = None, *,
                 backend: str = "auto", reuse: int = 32,
                 bcsr_block: int = 64, max_dia_offsets: int = 64,
                 bcsr_max_inflation: float = 64.0,
                 efficiency: Optional[Dict[str, Tuple[float, float]]] = None,
                 calibration=None,
                 tree=None, tree_margin: float = 0.10,
                 sizeof_val: int = 4, sizeof_idx: int = 4,
                 tolerance: float = 0.0):
        if backend not in ("auto", "jax", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        if not 0.0 <= tree_margin < 1.0:
            raise ValueError(f"tree_margin must be in [0, 1), "
                             f"got {tree_margin}")
        if tolerance < 0.0:
            raise ValueError(f"tolerance must be >= 0, got {tolerance}")
        self.backend = backend
        self.hardware = hardware
        self.reuse = reuse
        self.bcsr_block = bcsr_block
        self.max_dia_offsets = max_dia_offsets
        self.bcsr_max_inflation = bcsr_max_inflation
        self.efficiency = dict(DEFAULT_EFFICIENCY, **(efficiency or {}))
        #: Formats whose ceiling was pinned by the caller: calibration
        #: never overrides an explicit ``efficiency=`` entry.
        self._overridden = frozenset(efficiency or ())
        #: ``None`` = the default CalibrationStore (resolved lazily so
        #: ``$REPRO_CALIBRATION_DIR`` is honored at first use, not at
        #: import); a ``CalibrationStore`` to use explicitly; ``False``
        #: disables calibration lookup (the calibrator itself does this).
        self.calibration = calibration
        #: Learned dispatch fallback: ``None`` = the persisted tree from
        #: :class:`repro.data.dtree.DispatchTreeStore` (resolved lazily,
        #: like ``calibration``); a ``DecisionTree`` to use explicitly;
        #: ``False`` disables tree consultation entirely.  The tree is
        #: only consulted under ``strategy="auto"`` when the analytic
        #: top-two candidates sit within ``tree_margin`` (relative
        #: amortized-GFLOP/s gap) — the roofline model stays
        #: authoritative wherever it is confident.
        self.tree = tree
        self.tree_margin = tree_margin
        self._cal_cache: Dict[str, Dict[str, Tuple[float, float]]] = {}
        self._note_cache: Dict[tuple, Optional[str]] = {}
        self._tree_cache: Dict[str, Optional[DecisionTree]] = {}
        #: Legacy fp32 element sizes (kept for external byte-model
        #: callers, e.g. ``repro.sparse.shard``); the candidate models
        #: themselves size traffic from each row's ``Precision``.
        self.sizeof_val = sizeof_val
        self.sizeof_idx = sizeof_idx
        #: Default relative error budget of the accuracy gate: reduced
        #: value dtypes (bf16) are auto-eligible only when the budget
        #: covers the dtype's rounding (``tolerance >= eps``).  The fp32
        #: default 0.0 means "exact": auto dispatch never degrades
        #: numerics unless the caller opts in per-plan or per-dispatcher.
        self.tolerance = tolerance
        self._plans: Dict[tuple, DispatchPlan] = {}
        self._converted: Dict[tuple, object] = {}
        self._reports: Dict[int, StructureReport] = {}
        self._tracked: set = set()

    # ----------------------------------------------------------------- #
    # Cache plumbing
    # ----------------------------------------------------------------- #

    def _track(self, m: COOMatrix) -> int:
        key = id(m)
        if key not in self._tracked:
            self._tracked.add(key)
            weakref.finalize(m, _evict_cb, weakref.ref(self), key)
        return key

    def _evict(self, key: int) -> None:
        self._tracked.discard(key)
        self._reports.pop(key, None)
        for cache in (self._plans, self._converted):
            for k in [k for k in cache if k[0] == key]:
                cache.pop(k, None)

    def _report(self, m: COOMatrix) -> StructureReport:
        key = self._track(m)
        if key not in self._reports:
            self._reports[key] = classify(m)
        return self._reports[key]

    def convert(self, m: COOMatrix, format: str, precision=None):
        """Convert (and cache) m into ``format``'s container.

        ``precision`` (a :class:`Precision`, token string, or ``None``
        for fp32) sets the packed *value* dtype and is part of the cache
        key; containers keep int32 indices — compact int16 indices are a
        property of the Pallas layout packing, not of the container.
        """
        prec = as_precision(precision)
        key = (self._track(m), format, self.bcsr_block, prec.value_dtype)
        if key not in self._converted:
            dtype = prec.value_jnp
            if format == "csr":
                out = fmt.coo_to_csr(m, dtype=dtype)
            elif format == "ell":
                out = fmt.coo_to_ell(m, dtype=dtype)
            elif format == "bcsr":
                out = fmt.coo_to_bcsr(m, self.bcsr_block, dtype=dtype)
            elif format == "dia":
                out = fmt.coo_to_dia(m, dtype=dtype,
                                     max_offsets=self.max_dia_offsets)
            elif format == "binned":
                out = fmt.coo_to_binned(m, dtype=dtype)
            elif format == "rowsplit":
                out = fmt.coo_to_rowsplit(m, dtype=dtype, chunk=128)
            elif format == "ell_coo":
                out = fmt.coo_to_ell_coo(m, dtype=dtype)
            else:
                raise ValueError(f"unknown format {format!r}")
            self._converted[key] = out
        return self._converted[key]

    # ----------------------------------------------------------------- #
    # Modeling
    # ----------------------------------------------------------------- #

    def _calibrated(self, hw: HardwareSpec, backend: str,
                    precision: str = "f32i32"
                    ) -> Dict[str, Tuple[float, float]]:
        """The persisted calibration for ``(hw, backend)`` ({} if absent).

        The backend is part of the key: jax and pallas ceilings describe
        different kernel implementations, so a calibration fitted for one
        must never answer for the other.  ``precision`` selects
        dtype-specific fits where the calibration has them (ceilings are
        fitted per (format, dtype) since registry v4), falling back to
        the format's fp32 fit otherwise.
        """
        if self.calibration is False:
            return {}
        key = (hw.fingerprint(), backend, precision)
        if key not in self._cal_cache:
            store = self.calibration or CalibrationStore()
            cal = store.load(hw, backend)
            self._cal_cache[key] = \
                cal.efficiency(precision=precision) if cal else {}
        return self._cal_cache[key]

    def _staleness(self, hw: HardwareSpec, backend: str) -> Optional[str]:
        """The CalibrationStore's staleness note for ``(hw, backend)``,
        cached per fingerprint so planning does not reread the file."""
        if self.calibration is False:
            return None
        key = (hw.fingerprint(), backend)
        if key not in self._note_cache:
            store = self.calibration or CalibrationStore()
            self._note_cache[key] = store.staleness_note(hw, backend)
        return self._note_cache[key]

    def refresh_calibration(self) -> None:
        """Drop cached calibration/tree lookups and plans (e.g. after a
        new ``repro.core.calibrate.calibrate(..., store=...)`` run or a
        ``tools/harvest_dispatch.py`` refit)."""
        self._cal_cache.clear()
        self._note_cache.clear()
        self._tree_cache.clear()
        self._plans.clear()

    def _tree(self, backend: str) -> Optional[DecisionTree]:
        """Resolve the dispatch tree for ``backend`` (None = no tree).

        Mirrors :meth:`_calibrated`: an explicit ``tree=`` instance wins,
        ``tree=False`` disables lookup, and ``tree=None`` loads (and
        caches) the persisted ``dispatch_tree-<backend>.json`` from the
        default :class:`DispatchTreeStore` — absent or stale files
        resolve to ``None`` and dispatch stays purely analytic.
        """
        if self.tree is False:
            return None
        if isinstance(self.tree, DecisionTree):
            return self.tree
        if backend not in self._tree_cache:
            self._tree_cache[backend] = DispatchTreeStore().load(backend)
        return self._tree_cache[backend]

    def _ceiling(self, format: str, hw: HardwareSpec, backend: str,
                 precision: str = "f32i32") -> ComputeCeiling:
        """Resolve the compute ceiling with provenance.

        Order: an explicit ``efficiency=`` entry from the constructor
        ("override") > a persisted on-host calibration matching the
        HardwareSpec fingerprint and resolved backend ("calibrated",
        dtype-specific fit preferred, the format's fp32 fit as fallback)
        > the baked-in ``DEFAULT_EFFICIENCY`` constants ("default").
        """
        if format in self._overridden:
            return ComputeCeiling(*self.efficiency[format],
                                  source="override")
        calibrated = self._calibrated(hw, backend, precision)
        if format in calibrated:
            return ComputeCeiling(*calibrated[format], source="calibrated")
        return ComputeCeiling(*self.efficiency[format], source="default")

    def _resolve_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        return "pallas" if jax.default_backend() == "tpu" else "jax"

    def _resolve_hardware(self, backend: str) -> HardwareSpec:
        if self.hardware is not None:
            return self.hardware
        return TPU_V5E if backend == "pallas" and \
            jax.default_backend() == "tpu" else HOST_CPU

    def _policy(self, m: COOMatrix, report: StructureReport,
                format: str) -> Tuple[bool, Optional[str], dict]:
        """Applicability gate + the structural params the model needs.

        These are the benchmark suite's former inline heuristics, promoted
        to policy with recorded reasons (SpChar-style structural gating).
        """
        avg_deg, max_deg = _degree_stats(m)
        if format == "csr":
            return True, None, {}
        if format == "ell":
            k = max(max_deg, 1)
            params = {"k": k}
            if max_deg > max(64, 16 * max(avg_deg, 1)):
                return False, (
                    f"ELL padding explodes: max_deg {max_deg} >> avg "
                    f"{avg_deg:.1f} (vendor kernels fall back to CSR here)"
                ), params
            return True, None, params
        if format == "bcsr":
            t = self.bcsr_block
            if m.n % t != 0:
                return False, (f"matrix dim {m.n} not divisible by BCSR "
                               f"block {t}"), {}
            if report.stats.get("block_t") == t:
                bstats = {k[len("block_"):]: v for k, v in
                          report.stats.items() if k.startswith("block_")}
            else:
                bstats = block_stats(m, t)
            inflation = (t * t) / max(bstats["D"], 1e-9)
            params = {"t": t, "N": bstats["N"], "D": bstats["D"],
                      "z": bstats["z_emp"], "inflation": inflation}
            if inflation > self.bcsr_max_inflation:
                return False, (
                    f"dense-block inflation {inflation:.0f}x exceeds "
                    f"{self.bcsr_max_inflation:.0f}x (ai_blocked_tpu "
                    f"predicts mxu_util {1 / inflation:.3f})"), params
            return True, None, params
        if format == "dia":
            k = _num_diagonals(m)
            params = {"num_offsets": k}
            if k > self.max_dia_offsets:
                return False, (
                    f"{k} distinct diagonals exceed "
                    f"{self.max_dia_offsets}; DIA only suits banded "
                    f"matrices"), params
            return True, None, params
        if format in ("binned", "rowsplit"):
            # Both degrade gracefully on any structure (binned collapses
            # to CSR order when one slab covers the matrix; rowsplit's
            # padding is bounded by one chunk), so they are always
            # eligible — the roofline model, not a gate, decides.
            return True, None, {}
        if format == "ell_coo":
            deg = np.bincount(m.rows, minlength=m.n)
            k_cut = fmt.ell_coo_cutoff(deg)
            tail = int(np.clip(deg - k_cut, 0, None).sum())
            # The cutoff *is* the padding-explosion defense that forces
            # plain ELL to skip: hub rows overflow into the COO tail.
            return True, None, {"k_cut": k_cut, "tail_nnz": tail}
        raise ValueError(f"unknown format {format!r}")

    def _model(self, m: COOMatrix, report: StructureReport, format: str,
               params: dict, d: int, hw: HardwareSpec, reuse: int,
               backend: str, prec: Precision = DEFAULT_PRECISION
               ) -> Tuple[float, float, float, float, float, str]:
        """(ai, useful_fraction, predicted, amortized, conv_bytes, source).

        AI composes structure and storage: the B-traffic term comes from
        the detected regime's Section III model (structure controls B
        reuse no matter how A is stored), the A-traffic term from the
        format's actual storage footprint.  Every byte term is sized by
        ``prec``'s actual element widths — the precision axis changes
        *traffic*, not FLOPs, which is exactly why it moves the
        bandwidth roofline ``beta * AI``.
        """
        sv, si = prec.sizeof_val, prec.sizeof_idx
        n, nnz = m.n, m.nnz
        flops = sm.flops_spmm(nnz, d)
        regime_tb = report.traffic(d, sizeof_val=sv, sizeof_idx=si)
        bytes_b = regime_tb.bytes_b
        bytes_c = n * d * sv

        if format == "csr":
            bytes_a = nnz * (sv + si) + (n + 1) * si
            useful = 1.0
            conv = nnz * (sv + 2 * si) + (n + 1) * si   # data+cols+row_ids
        elif format == "ell":
            k = params["k"]
            bytes_a = n * k * (sv + si)
            useful = nnz / float(n * k)
            conv = n * k * (sv + si)
        elif format == "bcsr":
            t, N = params["t"], max(params["N"], 1)
            bytes_a = N * t * t * sv + 2 * N * si
            useful = sm.mxu_utilization(nnz, t, N)
            # Deterministic block reuse: Eq. 4's B term with measured z.
            bytes_b = 0.25 * N * params["z"] * d * sv
            conv = N * t * t * sv + 3 * N * si
        elif format == "dia":
            k = max(params["num_offsets"], 1)
            bytes_a = k * n * sv
            useful = nnz / float(k * n)
            # DIA's traversal streams B exactly once (Eq. 3) regardless of
            # the detected regime — that is the point of choosing it.
            bytes_b = n * d * sv
            conv = k * n * sv
        elif format == "binned":
            # Slab-binned traversal: B traffic is slabs fetched, not
            # nonzeros gathered — the scale-free regime's escape hatch
            # from the Eq. 2 worst case.  (Lazy import: repro.kernels
            # imports this package for its format containers.)
            from repro.kernels import registry as kreg
            slab = kreg.choose_b_tile(
                n, hw.vmem_bytes, bd=min(512, kreg.pallas_block_d(d)),
                sizeof_val=sv) or n
            touched, visits = kreg.binned_layout_stats(m, slab_rows=slab)
            tb = sm.ai_binned(n, nnz, d, slab_rows=slab,
                              slabs_touched=touched, num_visits=visits,
                              sizeof_val=sv, sizeof_idx=si)
            bytes_a, bytes_b, bytes_c = tb.bytes_a, tb.bytes_b, tb.bytes_c
            useful = 1.0
            # Conversion re-sorts the whole nonzero stream (an extra
            # binning pass over the layout on top of writing it).
            conv = 2.0 * (nnz * (sv + 2 * si) + (touched + 1) * si)
            params.update(slab_rows=slab, slabs_touched=touched,
                          num_visits=visits)
        elif format == "rowsplit":
            from repro.kernels import registry as kreg
            n_nonempty = int(np.unique(m.rows).shape[0])
            window = kreg.rowsplit_window_model(n_nonempty, nnz)
            # B locality is whatever the structural regime grants — the
            # row split changes load balance, not the gather pattern.
            tb = sm.ai_rowsplit(n, nnz, d, window=window,
                                bytes_b=regime_tb.bytes_b,
                                sizeof_val=sv, sizeof_idx=si)
            bytes_a, bytes_b, bytes_c = tb.bytes_a, tb.bytes_b, tb.bytes_c
            useful = 1.0
            conv = nnz * (sv + 2 * si)
            params.update(window=window)
        elif format == "ell_coo":
            k_cut, tail = params["k_cut"], params["tail_nnz"]
            issued = max(n * k_cut + tail, 1)
            # Body padding issues extra gathers; scale the regime's
            # per-gather B model by issued/nnz to charge for them.
            tb = sm.ai_ell_coo(
                n, nnz, d, k_cut=k_cut, tail_nnz=tail,
                bytes_b=regime_tb.bytes_b * issued / max(nnz, 1),
                sizeof_val=sv, sizeof_idx=si)
            bytes_a, bytes_b, bytes_c = tb.bytes_a, tb.bytes_b, tb.bytes_c
            useful = nnz / float(issued)
            conv = n * k_cut * (sv + si) + tail * (sv + 2 * si)
        else:
            raise ValueError(f"unknown format {format!r}")

        ai = flops / (bytes_a + bytes_b + bytes_c)
        bandwidth_roof = hw.hbm_bandwidth * ai
        ceiling = self._ceiling(format, hw, backend, prec.token)
        compute_roof = ceiling.attainable(hw.peak_flops, useful, d)
        predicted = min(bandwidth_roof, compute_roof)
        if flops <= 0 or predicted <= 0:   # empty matrix: nothing to do
            return ai, useful, 0.0, 0.0, conv, ceiling.source
        t_spmm = flops / predicted
        t_conv = 2.0 * conv / hw.hbm_bandwidth          # read COO + write
        amortized = flops / (t_spmm + t_conv / max(reuse, 1))
        return (ai, useful, predicted / 1e9, amortized / 1e9, conv,
                ceiling.source)

    def _index_extent(self, m: COOMatrix, format: str, d: int,
                      hw: HardwareSpec, prec: Precision) -> int:
        """The largest extent a packed index of this layout addresses.

        Slab-streamed Pallas layouts (csr / ell / binned / ell_coo)
        store slab-local column ids, so the extent is the B row-slab
        size (the whole matrix when B fits unstreamed); the rowsplit
        packing keeps *global* column ids, so its extent is always n.
        Matches the packers' own ``index_extent_check`` at prepare time.
        """
        if format == "rowsplit":
            return m.n
        from repro.kernels import registry as kreg
        bt = kreg.choose_b_tile(
            m.n, hw.vmem_bytes, bd=min(512, kreg.pallas_block_d(d)),
            sizeof_val=prec.sizeof_val)
        return m.n if bt is None else bt

    def _precision_gate(self, m: COOMatrix, format: str, prec: Precision,
                        d: int, hw: HardwareSpec, tolerance: float,
                        forced: bool) -> Tuple[bool, Optional[str]]:
        """Accuracy/legality gate for one (format, precision) row.

        int16 extent legality is *correctness* and never waived; the
        bf16 tolerance gate is *preference* — an explicitly forced
        ``precision=`` is itself the opt-in and bypasses it, exactly as
        a forced strategy bypasses the auto ranking (but not policy).
        """
        if prec.index_dtype == "int16":
            extent = self._index_extent(m, format, d, hw, prec)
            if not fmt.int16_extent_ok(extent):
                return False, (
                    f"int16 indices cannot address extent {extent} "
                    f"(> {INT16_MAX_EXTENT}; the packers reserve a "
                    f"sentinel slot equal to the extent)")
        if prec.reduced and not forced and tolerance < prec.eps:
            return False, (
                f"bf16 values round at eps={prec.eps:.1e} > tolerance "
                f"{tolerance:.1e}; pass tolerance= or force precision= "
                f"to opt in")
        return True, None

    # ----------------------------------------------------------------- #
    # Public API
    # ----------------------------------------------------------------- #

    def plan(self, m: COOMatrix, d: int, *, strategy: str = "auto",
             reuse: Optional[int] = None, precision=None,
             tolerance: Optional[float] = None) -> DispatchPlan:
        """Plan (and cache) the (format, kernel) choice for ``(m, d)``.

        Args:
            m: square sparse pattern, ``[n, n]``.
            d: dense operand width (``B`` is ``[n, d]``).
            strategy: ``"auto"`` (roofline-predicted best) or a format name
                from ``FORMATS`` to force that format.
            reuse: conversion amortization horizon — the expected number of
                SpMM executions this plan will serve.  Defaults to the
                dispatcher's ``reuse`` (32).  Higher values let formats with
                expensive one-time conversions (e.g. BCSR's dense blocks)
                win on amortized throughput.
            precision: force one precision (a token like ``"bf16"`` /
                ``"bf16i16"`` / ``"fp32"``, or a ``Precision``) the way a
                format name forces ``strategy`` — restricting every
                candidate to that row.  Forcing is itself the accuracy
                opt-in (the bf16 tolerance gate is waived), but int16
                extent legality still raises.  ``None`` enumerates every
                precision each kernel supports and lets the roofline
                ranking pick.
            tolerance: relative error budget of the accuracy gate for
                this plan (defaults to the dispatcher's ``tolerance``,
                0.0).  Reduced value dtypes are auto-eligible only when
                ``tolerance >= dtype eps`` (bf16: ~7.8e-3); gated rows
                stay in the audit with a recorded skip reason.

        Under ``strategy="auto"``, when a fitted dispatch tree is
        available (see the ``tree`` constructor arg) and the analytic
        top-two candidates sit within ``tree_margin`` of each other, the
        tree breaks the tie; the plan records ``decision_source="tree"``
        and the tree's ``decision_path``.

        Returns:
            The cached :class:`DispatchPlan` with per-candidate predictions.

        Raises:
            ValueError: on an unknown strategy, ``d < 1``, a forced
                format the applicability policy rejects for this matrix
                (the error carries the recorded skip reason), or a forced
                precision no eligible kernel can run here (unsupported
                by the (format, backend) specs, or int16-illegal extent).
        """
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; choose from "
                             f"{STRATEGIES}")
        if d < 1:
            raise ValueError(f"dense width d must be >= 1, got {d}")
        reuse = self.reuse if reuse is None else reuse
        tolerance = self.tolerance if tolerance is None else float(tolerance)
        forced_tok = None if precision is None \
            else as_precision(precision).token
        backend = self._resolve_backend()
        hw = self._resolve_hardware(backend)
        # The fitted tree is part of the plan identity: refitting (or
        # deleting) the persisted tree must not replay stale decisions.
        tree = self._tree(backend) if strategy == "auto" else None
        tree_token = tree.fingerprint() if tree is not None else "none"
        key = (self._track(m), d, strategy, reuse, backend, hw.name,
               tree_token, self.tree_margin, forced_tok, tolerance)
        if key in self._plans:
            return self._plans[key]

        from repro.kernels import registry as kreg
        report = self._report(m)
        cands = []
        for f in FORMATS:
            eligible, reason, params = self._policy(m, report, f)
            spec_tokens = kreg.get(f, backend).supported_precisions
            for prec in PRECISIONS:
                if prec.token not in spec_tokens:
                    continue
                p_ok, p_reason = self._precision_gate(
                    m, f, prec, d, hw, tolerance,
                    forced=prec.token == forced_tok)
                source = "default"
                row_params = dict(params)
                try:
                    ai, useful, pred, amort, conv, source = self._model(
                        m, report, f, row_params, d, hw, reuse, backend,
                        prec)
                except (KeyError, ValueError):
                    ai = useful = pred = amort = conv = None
                cands.append(CandidateEval(
                    format=f, eligible=eligible and p_ok,
                    skip_reason=reason if not eligible else p_reason,
                    ai=ai, useful_fraction=useful, predicted_gflops=pred,
                    amortized_gflops=amort, conversion_bytes=conv,
                    params=row_params, ceiling_source=source,
                    precision=prec.token))

        pool = cands if forced_tok is None else \
            [c for c in cands if c.precision == forced_tok]
        decision_source, decision_path = "analytic", ()
        if strategy == "auto":
            viable = [c for c in pool
                      if c.eligible and c.amortized_gflops is not None]
            if not viable:
                if forced_tok is not None:
                    raise ValueError(
                        f"no eligible kernel on backend {backend!r} can "
                        f"run precision {forced_tok!r} for this matrix")
                # CSR at fp32 is always eligible; belt and braces.
                viable = [c for c in cands
                          if c.format == "csr" and c.precision == "f32i32"]
            ranked = sorted(viable, key=lambda c: c.amortized_gflops or 0.0,
                            reverse=True)
            # Tie-breaking and the tree speak *formats*: collapse to the
            # best precision row per format before ranking gaps, so two
            # precisions of one format never masquerade as a near-tie.
            best_by_fmt: Dict[str, CandidateEval] = {}
            for c in ranked:
                best_by_fmt.setdefault(c.format, c)
            franked = list(best_by_fmt.values())
            chosen_c = franked[0]
            # Learned fallback (SpChar): only where the analytic model
            # cannot separate its top two candidates.  The tree's pick
            # must itself be within the margin of the analytic winner —
            # the tree breaks ties, it never overrules a confident
            # roofline ranking — so any tree-induced regression is
            # bounded by tree_margin by construction.
            if tree is not None and len(franked) >= 2:
                top = franked[0].amortized_gflops or 0.0
                gap = (top - (franked[1].amortized_gflops or 0.0)) \
                    / max(top, 1e-12)
                if gap <= self.tree_margin:
                    x = features_from_report(report, d)
                    pick = tree.predict(x)
                    near = {c.format for c in franked
                            if top - (c.amortized_gflops or 0.0)
                            <= self.tree_margin * top}
                    if pick in near:
                        chosen_c = best_by_fmt[pick]
                        decision_source = "tree"
                        decision_path = tree.decision_path(x)
        else:
            rows = [c for c in pool if c.format == strategy]
            if not rows:
                raise ValueError(
                    f"kernel ({strategy!r}, {backend!r}) does not "
                    f"support precision {forced_tok!r}")
            eligible_rows = [c for c in rows if c.eligible]
            if not eligible_rows:
                raise ValueError(
                    f"strategy {strategy!r} is policy-ineligible for "
                    f"this matrix: {rows[0].skip_reason}")
            chosen_c = max(eligible_rows,
                           key=lambda c: c.amortized_gflops or 0.0)
        plan = DispatchPlan(
            chosen=chosen_c.format, strategy=strategy, regime=report.regime,
            d=d, reuse=reuse, backend=backend, hardware=hw.name,
            candidates=tuple(cands), precision=chosen_c.precision,
            tolerance=tolerance,
            calibration_note=self._staleness(hw, backend),
            decision_source=decision_source, decision_path=decision_path)
        self._plans[key] = plan
        return plan

    def spmm(self, m: COOMatrix, b: jnp.ndarray, *,
             strategy: str = "auto",
             reuse: Optional[int] = None, precision=None,
             tolerance: Optional[float] = None) -> jnp.ndarray:
        """Compute ``C = A @ B`` through the planned (format, kernel) pair.

        Args:
            m: square sparse pattern, ``[n, n]``.
            b: dense right-hand side, ``[n, d]``.
            strategy: ``"auto"`` or a forced format name (see :meth:`plan`).
            reuse: conversion amortization horizon (see :meth:`plan`).
            precision: force one storage precision (see :meth:`plan`).
            tolerance: accuracy-gate budget for reduced precisions (see
                :meth:`plan`).

        Returns:
            ``C`` as a dense ``[n, d]`` array.  Under a reduced plan
            precision the kernel rounds B to the storage dtype and
            returns C in it (accumulation stays fp32 throughout).

        Raises:
            ValueError: on a shape-incompatible ``b``, or a forced format
                / precision the policy rejects for this matrix (see
                :meth:`plan`).
        """
        if b.ndim != 2 or b.shape[0] != m.n:
            raise ValueError(
                f"operand shape {tuple(b.shape)} incompatible with "
                f"[{m.n}, {m.n}] sparse matrix; expected [{m.n}, d]")
        plan = self.plan(m, int(b.shape[1]), strategy=strategy, reuse=reuse,
                         precision=precision, tolerance=tolerance)
        return self.executor(m, plan)(b)

    def executor(self, m: COOMatrix,
                 plan: DispatchPlan) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Bind ``plan`` to ``m``: the execute phase, split from planning.

        All one-time work — format conversion and host-side kernel layout
        packing (row-tile chunking, band extraction, empty-block-row
        padding) — happens here, once; the returned closure holds the
        prepared containers directly, so replaying it across many
        right-hand sides does no cache lookups, no classification, and no
        conversion.  This is the primitive under
        :class:`repro.sparse.stream.StreamPlan`.

        Args:
            m: the matrix the plan was made for.
            plan: a :class:`DispatchPlan` from :meth:`plan`.

        Returns:
            ``run(b) -> c`` executing the chosen kernel; ``b`` is ``[n, d]``
            (any ``d`` — the kernel tile width adapts per call), ``c`` is
            ``[n, d]``.
        """
        # Uniform path: resolve the KernelSpec for (format, backend) and
        # cache its prepared layout per matrix — per-call packing would
        # dominate the kernel.  (Lazy import: repro.kernels imports this
        # package for its format containers.)
        from repro.kernels import registry
        spec = registry.get(plan.chosen, plan.backend)
        prec = as_precision(plan.precision)

        def _convert(mm, format, _prec=prec):
            # prepare shares the conversion cache, pinned to the plan's
            # precision (the registry's hook is two-argument).
            return self.convert(mm, format, precision=_prec)

        ctx = registry.KernelContext(
            hardware=self._resolve_hardware(plan.backend),
            bcsr_block=self.bcsr_block,
            max_dia_offsets=self.max_dia_offsets,
            plan_d=plan.d,          # per-d B-slab re-packing
            precision=prec,         # dtype-sized slabs, packed indices
            convert=_convert)
        # The resolved d-tile and the storage precision are part of the
        # layout identity: two plans whose widths map to different slab
        # sizings, or whose layouts pack different dtypes, must not
        # share one packed layout.
        ck = (self._track(m), "layout", *spec.layout_cache_key,
              self.bcsr_block, registry.pallas_block_d(plan.d),
              prec.token)
        if ck not in self._converted:
            self._converted[ck] = spec.prepare(m, ctx)
        layout = self._converted[ck]
        return lambda b: spec.run(layout, b, ctx)


#: Module-level dispatcher behind the one-call public API.
_DEFAULT = Dispatcher()


def default_dispatcher() -> Dispatcher:
    """Return the module-level :class:`Dispatcher` behind ``spmm``/``plan_spmm``."""
    return _DEFAULT


def plan_spmm(m: COOMatrix, d: int, *, strategy: str = "auto",
              reuse: Optional[int] = None, precision=None,
              tolerance: Optional[float] = None) -> DispatchPlan:
    """Plan the (format, kernel) choice for ``(m, d)`` on the default dispatcher.

    Args:
        m: square sparse pattern (``repro.core.patterns.COOMatrix``), [n, n].
        d: dense operand width.
        strategy: ``"auto"`` or a format from ``FORMATS`` to force.
        reuse: conversion amortization horizon (default 32 executions).
        precision: ``None`` (enumerate every supported precision, gated
            by ``tolerance``) or a forced precision token / ``Precision``
            (``"fp32"``, ``"bf16"``, ``"bf16i32"``, ``"bf16i16"``).
        tolerance: relative error budget enabling reduced value dtypes
            (bf16 needs ~7.8e-3); default 0.0 keeps dispatch exact.

    Returns:
        An inspectable :class:`DispatchPlan`; ``plan.summary()`` renders the
        per-candidate predictions, precisions, and skip reasons.

    Raises:
        ValueError: on an unknown strategy, ``d < 1``, or a forced format
            / precision the policy rejects for this matrix.
    """
    return _DEFAULT.plan(m, d, strategy=strategy, reuse=reuse,
                         precision=precision, tolerance=tolerance)


def spmm(m: COOMatrix, b: jnp.ndarray, *, strategy: str = "auto",
         reuse: Optional[int] = None, precision=None,
         tolerance: Optional[float] = None) -> jnp.ndarray:
    """Structure-aware SpMM: ``C = A @ B`` via the default dispatcher.

    ``strategy="auto"`` classifies the matrix structure, evaluates each
    candidate format's sparsity-aware roofline, and executes the winning
    (format, kernel) pair; a format name forces that format.  Plans and
    conversions are cached per matrix.  For a stream of right-hand sides
    against one matrix, prefer :func:`repro.sparse.stream.plan` — it binds
    the kernel once and replays it with zero dispatch overhead.

    Args:
        m: square sparse pattern (``repro.core.patterns.COOMatrix``), [n, n].
        b: dense right-hand side, ``[n, d]``.
        strategy: ``"auto"`` or a format from ``FORMATS`` to force.
        reuse: conversion amortization horizon (default 32 executions).
        precision: ``None`` or a forced precision (see :func:`plan_spmm`).
        tolerance: accuracy-gate budget enabling reduced precisions; with
            the default 0.0 dispatch stays fp32-exact.

    Returns:
        ``C`` as a dense ``[n, d]`` array (in the plan's value dtype:
        fp32 unless a reduced precision was chosen or forced).

    Raises:
        ValueError: on a shape-incompatible ``b``, or a forced format /
            precision the applicability policy rejects for this matrix
            (the error carries the recorded skip reason).
    """
    return _DEFAULT.spmm(m, b, strategy=strategy, reuse=reuse,
                         precision=precision, tolerance=tolerance)
