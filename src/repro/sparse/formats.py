"""Sparse matrix containers as JAX pytrees (CSR / ELL / BCSR / DIA).

These mirror the paper's evaluated layouts:

  CSR    row-pointer format — the paper's baseline implementation.
  ELL    padded row format — stands in for the vendor (MKL-style) kernel:
         fully vectorizable, wasteful on skewed rows.
  BCSR   dense t x t blocks with block-CSR indexing — the TPU adaptation of
         the paper's CSB (Compressed Sparse Blocks): every nonzero block is
         stored densely so the MXU can consume it directly.
  DIA    banded/diagonal storage — realizes the paper's diagonal regime.

Three scale-free-regime layouts ride on top (PR 8):

  BINNED   slab-binned COO: nonzeros grouped by B-row slab with CSC-like
           ordering inside each slab (propagation-blocking, arXiv
           2002.11302) — the layout behind the two-phase binned kernel.
  ROWSPLIT equal-nnz work chunks over the CSR nonzero stream (merge-path
           style load balancing for skewed degree distributions).
  ELL_COO  sorted-ELL body up to a per-matrix width cutoff plus a COO
           tail for the overflow (hybrid storage, arXiv 2005.14469).

All arrays are jnp; static shape information (n, t, nnz) lives in aux data so
the containers jit cleanly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _register(cls, data_fields: Tuple[str, ...], meta_fields: Tuple[str, ...]):
    jax.tree_util.register_dataclass(cls, list(data_fields), list(meta_fields))
    return cls


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """CSR with a precomputed per-nonzero row-id vector (segment ids)."""

    data: jnp.ndarray      # [nnz] values
    indices: jnp.ndarray   # [nnz] column ids (int32)
    indptr: jnp.ndarray    # [n+1] row pointers (int32)
    row_ids: jnp.ndarray   # [nnz] row id per nonzero (int32)
    n: int                 # static

    @property
    def nnz(self) -> int:
        """Number of stored nonzeros."""
        return int(self.data.shape[0])


_register(CSRMatrix, ("data", "indices", "indptr", "row_ids"), ("n",))


@dataclasses.dataclass(frozen=True)
class ELLMatrix:
    """Padded (ELLPACK) layout: fixed nonzeros-per-row with a validity mask."""

    data: jnp.ndarray      # [n, k] values, zero-padded
    indices: jnp.ndarray   # [n, k] column ids, padded with 0
    n: int                 # static

    @property
    def k(self) -> int:
        """Padded slots per row (the max row degree at conversion time)."""
        return int(self.data.shape[1])


_register(ELLMatrix, ("data", "indices"), ("n",))


@dataclasses.dataclass(frozen=True)
class BCSRMatrix:
    """Block-CSR with dense t x t blocks (TPU CSB analogue).

    ``block_rows``/``block_cols`` are per-nonzero-block coordinates in block
    space; blocks are sorted by (block_row, block_col) so a block row is a
    contiguous slice — the Pallas kernel walks ``block_ptr`` like CSR walks
    ``indptr``.
    """

    blocks: jnp.ndarray      # [N, t, t] dense block values
    block_rows: jnp.ndarray  # [N] block-row id (int32)
    block_cols: jnp.ndarray  # [N] block-col id (int32)
    block_ptr: jnp.ndarray   # [nb+1] first block of each block row (int32)
    n: int                   # static: matrix dimension
    t: int                   # static: block edge
    nnz: int                 # static: true nonzeros (for FLOP accounting)

    @property
    def num_blocks(self) -> int:
        """Count of stored (nonzero) t x t blocks — the paper's N."""
        return int(self.blocks.shape[0])

    @property
    def nb(self) -> int:
        """Number of block rows/cols (n / t)."""
        return self.n // self.t


_register(BCSRMatrix, ("blocks", "block_rows", "block_cols", "block_ptr"),
          ("n", "t", "nnz"))


@dataclasses.dataclass(frozen=True)
class DIAMatrix:
    """Diagonal storage: one row of values per stored offset."""

    data: jnp.ndarray      # [num_offsets, n] values (zero where out of band)
    offsets: Tuple[int, ...]  # static diagonal offsets
    n: int                 # static

    @property
    def num_offsets(self) -> int:
        """Number of stored diagonals."""
        return int(self.data.shape[0])


_register(DIAMatrix, ("data",), ("offsets", "n"))


@dataclasses.dataclass(frozen=True)
class BinnedMatrix:
    """Slab-binned COO: nonzeros grouped by B-row slab (bin = ``col //
    slab_rows``), CSC-like (column-major) inside each slab.

    The two-phase binned kernel's layout: phase one (conversion) pays one
    streaming pass to produce this ordering; phase two accumulates each
    slab's contributions while that B slab is VMEM/cache resident, so B
    traffic is one read per touched slab instead of one gather per
    nonzero.
    """

    data: jnp.ndarray      # [nnz] values, slab-major order
    cols: jnp.ndarray      # [nnz] column ids (int32), ascending per slab
    rows: jnp.ndarray      # [nnz] row id per nonzero (int32)
    slab_ptr: jnp.ndarray  # [num_slabs+1] first nonzero of each slab (int32)
    slab_rows: int         # static: B rows per slab
    n: int                 # static

    @property
    def nnz(self) -> int:
        """Number of stored nonzeros."""
        return int(self.data.shape[0])

    @property
    def num_slabs(self) -> int:
        """Number of B-row slabs (ceil(n / slab_rows))."""
        return int(self.slab_ptr.shape[0]) - 1


_register(BinnedMatrix, ("data", "cols", "rows", "slab_ptr"),
          ("slab_rows", "n"))


@dataclasses.dataclass(frozen=True)
class RowSplitMatrix:
    """Equal-nnz work chunks over the row-major nonzero stream.

    The CSR stream is cut into chunks of exactly ``chunk`` nonzeros
    (merge-path style), so a hub row spans many chunks instead of
    serializing one worker — the load-balance answer to skewed degree
    distributions.  The stream is zero-padded to whole chunks (padding
    rows point at row 0 with value 0, contributing nothing).
    """

    data: jnp.ndarray   # [P] values, row-major, zero-padded
    cols: jnp.ndarray   # [P] column ids (int32), 0-padded
    rows: jnp.ndarray   # [P] row id per nonzero (int32), 0-padded
    chunk: int          # static: nonzeros per equal-work chunk
    n: int              # static
    nnz: int            # static: true nonzeros (excludes padding)

    @property
    def num_chunks(self) -> int:
        """Number of equal-work chunks (padded length / chunk)."""
        return int(self.data.shape[0]) // self.chunk


_register(RowSplitMatrix, ("data", "cols", "rows"), ("chunk", "n", "nnz"))


@dataclasses.dataclass(frozen=True)
class ELLCOOMatrix:
    """Hybrid layout: sorted-ELL body + COO tail above a width cutoff.

    Each row's column-sorted nonzeros fill up to ``k_cut`` padded body
    slots; the overflow (hub rows' long tails) lands in a row-major COO
    tail.  The body is fully vectorizable like ELL but the cutoff is
    chosen per matrix so power-law rows cannot blow up the padding.
    """

    body_data: jnp.ndarray     # [n, k_cut] values, zero-padded
    body_indices: jnp.ndarray  # [n, k_cut] column ids, padded with 0
    tail_data: jnp.ndarray     # [tail_nnz] overflow values
    tail_cols: jnp.ndarray     # [tail_nnz] overflow column ids (int32)
    tail_rows: jnp.ndarray     # [tail_nnz] overflow row ids (int32)
    n: int                     # static
    nnz: int                   # static: true nonzeros

    @property
    def k_cut(self) -> int:
        """Padded body slots per row (the per-matrix width cutoff)."""
        return int(self.body_data.shape[1])

    @property
    def tail_nnz(self) -> int:
        """Nonzeros stored in the COO tail."""
        return int(self.tail_data.shape[0])


_register(ELLCOOMatrix,
          ("body_data", "body_indices", "tail_data", "tail_cols",
           "tail_rows"),
          ("n", "nnz"))


# --------------------------------------------------------------------------
# Precision: the registry's third dispatch axis (after format and backend).
# The implementation lives in repro.core.precision so the kernel registry
# can import it without a package cycle; this module is its public home.
# --------------------------------------------------------------------------

from repro.core.precision import (  # noqa: F401,E402  (re-export)
    DEFAULT_PRECISION, INT16_MAX_EXTENT, PRECISION_BF16, PRECISION_BF16_I32,
    PRECISION_FP32, PRECISIONS, Precision, as_precision, int16_extent_ok)


# --------------------------------------------------------------------------
# Converters from the numpy COO patterns (repro.core.patterns.COOMatrix).
# --------------------------------------------------------------------------

def coo_to_csr(m, dtype=jnp.float32) -> CSRMatrix:
    """Convert a COO pattern to CSR.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.

    Returns:
        :class:`CSRMatrix` with row-major-sorted ``data``/``indices``
        ([nnz]), ``indptr`` ([n+1]), and precomputed ``row_ids`` ([nnz]).
    """
    order = np.lexsort((m.cols, m.rows))
    rows = m.rows[order]
    cols = m.cols[order]
    vals = m.vals[order].astype(dtype)
    counts = np.bincount(rows, minlength=m.n)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return CSRMatrix(
        data=jnp.asarray(vals),
        indices=jnp.asarray(cols.astype(np.int32)),
        indptr=jnp.asarray(indptr),
        row_ids=jnp.asarray(rows.astype(np.int32)),
        n=m.n,
    )


def coo_to_ell(m, dtype=jnp.float32, max_k: int | None = None) -> ELLMatrix:
    """Convert a COO pattern to padded ELLPACK.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.
        max_k: cap on slots per row; defaults to the max row degree.
            Entries beyond the cap are dropped (callers gate on padding
            blow-up before choosing ELL).

    Returns:
        :class:`ELLMatrix` with zero-padded ``data``/``indices`` [n, k].
    """
    counts = np.bincount(m.rows, minlength=m.n)
    k = int(counts.max()) if max_k is None else max_k
    k = max(k, 1)
    data = np.zeros((m.n, k), dtype=dtype)
    indices = np.zeros((m.n, k), dtype=np.int32)
    slot = np.zeros(m.n, dtype=np.int64)
    order = np.lexsort((m.cols, m.rows))
    for r, c, v in zip(m.rows[order], m.cols[order], m.vals[order]):
        s = slot[r]
        if s < k:
            data[r, s] = v
            indices[r, s] = c
            slot[r] = s + 1
    return ELLMatrix(data=jnp.asarray(data), indices=jnp.asarray(indices),
                     n=m.n)


def coo_to_bcsr(m, t: int, dtype=jnp.float32) -> BCSRMatrix:
    """Convert a COO pattern to dense-block BCSR.

    Args:
        m: ``repro.core.patterns.COOMatrix``; ``m.n`` must divide by ``t``.
        t: block edge (t x t dense blocks).
        dtype: value dtype of the blocks.

    Returns:
        :class:`BCSRMatrix` with ``blocks`` [N, t, t] sorted by
        (block_row, block_col) and CSR-style ``block_ptr`` [nb+1].

    Raises:
        ValueError: if ``m.n`` is not a multiple of ``t``.
    """
    if m.n % t != 0:
        raise ValueError(f"matrix dim {m.n} not divisible by block size {t}")
    bi = m.rows.astype(np.int64) // t
    bj = m.cols.astype(np.int64) // t
    nb = m.n // t
    blin = bi * nb + bj
    uniq, inverse = np.unique(blin, return_inverse=True)
    N = uniq.shape[0]
    blocks = np.zeros((N, t, t), dtype=dtype)
    rr = m.rows % t
    cc = m.cols % t
    blocks[inverse, rr, cc] = m.vals.astype(dtype)
    block_rows = (uniq // nb).astype(np.int32)
    block_cols = (uniq % nb).astype(np.int32)
    counts = np.bincount(block_rows, minlength=nb)
    block_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return BCSRMatrix(
        blocks=jnp.asarray(blocks),
        block_rows=jnp.asarray(block_rows),
        block_cols=jnp.asarray(block_cols),
        block_ptr=jnp.asarray(block_ptr),
        n=m.n, t=t, nnz=m.nnz,
    )


def coo_to_dia(m, dtype=jnp.float32, max_offsets: int = 64) -> DIAMatrix:
    """Convert a COO pattern to diagonal (DIA) storage.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.
        max_offsets: refuse matrices with more distinct diagonals than
            this (DIA storage is k*n values; only banded matrices fit).

    Returns:
        :class:`DIAMatrix` with ``data`` [num_offsets, n] indexed by row.

    Raises:
        ValueError: if the matrix has more than ``max_offsets`` diagonals.
    """
    offs = np.unique(m.cols.astype(np.int64) - m.rows)
    if offs.shape[0] > max_offsets:
        raise ValueError(
            f"{offs.shape[0]} distinct diagonals exceeds max_offsets="
            f"{max_offsets}; DIA only suits banded matrices")
    data = np.zeros((offs.shape[0], m.n), dtype=dtype)
    off_index = {int(o): i for i, o in enumerate(offs)}
    for r, c, v in zip(m.rows, m.cols, m.vals):
        data[off_index[int(c) - int(r)], r] = v
    return DIAMatrix(data=jnp.asarray(data),
                     offsets=tuple(int(o) for o in offs), n=m.n)


def default_slab_rows(n: int) -> int:
    """Deterministic default B-slab height for :func:`coo_to_binned`.

    The jax-backend container only encodes traversal order, so any slab
    height is numerically equivalent; 512 rows (a 2 KiB-per-column slab)
    is a stand-in for one cache-resident B slab.  The Pallas path sizes
    its slabs from ``HardwareSpec.vmem_bytes`` instead
    (``registry.choose_b_tile``).
    """
    return max(1, min(n, 512))


def coo_to_binned(m, dtype=jnp.float32,
                  slab_rows: int | None = None) -> BinnedMatrix:
    """Convert a COO pattern to the slab-binned layout.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.
        slab_rows: B rows per slab (bin = column // slab_rows); defaults
            to :func:`default_slab_rows`.

    Returns:
        :class:`BinnedMatrix` sorted by (slab, column, row) — the
        binning pass — with CSR-style ``slab_ptr``.
    """
    slab_rows = default_slab_rows(m.n) if slab_rows is None else slab_rows
    if slab_rows < 1:
        raise ValueError(f"slab_rows must be >= 1, got {slab_rows}")
    slabs = m.cols.astype(np.int64) // slab_rows
    order = np.lexsort((m.rows, m.cols, slabs))
    num_slabs = max(1, -(-m.n // slab_rows))
    counts = np.bincount(slabs[order], minlength=num_slabs)
    slab_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return BinnedMatrix(
        data=jnp.asarray(m.vals[order].astype(dtype)),
        cols=jnp.asarray(m.cols[order].astype(np.int32)),
        rows=jnp.asarray(m.rows[order].astype(np.int32)),
        slab_ptr=jnp.asarray(slab_ptr),
        slab_rows=slab_rows, n=m.n,
    )


def coo_to_rowsplit(m, dtype=jnp.float32, chunk: int = 128) -> RowSplitMatrix:
    """Convert a COO pattern to equal-nnz work chunks.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.
        chunk: nonzeros per work chunk (the merge-path grain).

    Returns:
        :class:`RowSplitMatrix` with the row-major stream zero-padded to
        a whole number of chunks.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    order = np.lexsort((m.cols, m.rows))
    padded = -(-max(m.nnz, 0) // chunk) * chunk
    data = np.zeros(padded, dtype=dtype)
    cols = np.zeros(padded, dtype=np.int32)
    rows = np.zeros(padded, dtype=np.int32)
    data[:m.nnz] = m.vals[order].astype(dtype)
    cols[:m.nnz] = m.cols[order]
    rows[:m.nnz] = m.rows[order]
    return RowSplitMatrix(data=jnp.asarray(data), cols=jnp.asarray(cols),
                          rows=jnp.asarray(rows), chunk=chunk, n=m.n,
                          nnz=m.nnz)


def ell_coo_cutoff(row_degrees) -> int:
    """Storage-optimal ELL body width for the hybrid ELL/COO layout.

    Minimizes ``n * k + 2 * tail_nnz(k)`` over cutoffs ``k``: each body
    slot stores (value, column) for every row, while a tail entry stores
    (value, row, column) — roughly 2x the per-entry cost but only for the
    overflow.  On power-law degree distributions the optimum sits near
    the median degree, so hub rows spill to the tail instead of padding
    every row to the hub width.

    Args:
        row_degrees: per-row nonzero counts, length ``n``.

    Returns:
        The cutoff ``k >= 1``.
    """
    deg = np.asarray(row_degrees, dtype=np.int64).ravel()
    n = deg.shape[0]
    kmax = int(deg.max()) if n else 1
    if kmax <= 1:
        return 1
    hist = np.bincount(deg, minlength=kmax + 1)
    rows_gt = n - np.cumsum(hist[:kmax + 1])       # rows with degree > j
    # tail(k) = sum_{j >= k} rows_gt[j]  (suffix sums of rows_gt)
    suffix = np.concatenate([np.cumsum(rows_gt[::-1])[::-1], [0]])
    k_values = np.arange(1, kmax + 1)
    cost = n * k_values + 2 * suffix[1:kmax + 1]
    return int(k_values[int(np.argmin(cost))])


def coo_to_ell_coo(m, dtype=jnp.float32,
                   k_cut: int | None = None) -> ELLCOOMatrix:
    """Convert a COO pattern to the hybrid sorted-ELL + COO-tail layout.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.
        k_cut: body width cutoff; defaults to the storage-optimal
            :func:`ell_coo_cutoff` of the row-degree distribution.

    Returns:
        :class:`ELLCOOMatrix`: each row's column-sorted nonzeros fill up
        to ``k_cut`` body slots; the overflow goes to a row-major tail.
    """
    deg = np.bincount(m.rows, minlength=m.n)
    if k_cut is None:
        k_cut = ell_coo_cutoff(deg) if m.nnz else 1
    k_cut = max(1, int(k_cut))
    order = np.lexsort((m.cols, m.rows))
    rows = m.rows[order].astype(np.int64)
    cols = m.cols[order]
    vals = m.vals[order].astype(dtype)
    indptr = np.concatenate([[0], np.cumsum(deg)])
    slot = np.arange(rows.shape[0], dtype=np.int64) - indptr[rows]
    in_body = slot < k_cut
    body_data = np.zeros((m.n, k_cut), dtype=dtype)
    body_indices = np.zeros((m.n, k_cut), dtype=np.int32)
    body_data[rows[in_body], slot[in_body]] = vals[in_body]
    body_indices[rows[in_body], slot[in_body]] = cols[in_body]
    tail = ~in_body
    return ELLCOOMatrix(
        body_data=jnp.asarray(body_data),
        body_indices=jnp.asarray(body_indices),
        tail_data=jnp.asarray(vals[tail]),
        tail_cols=jnp.asarray(cols[tail].astype(np.int32)),
        tail_rows=jnp.asarray(rows[tail].astype(np.int32)),
        n=m.n, nnz=m.nnz,
    )


# --------------------------------------------------------------------------
# Shard splitters (consumed by repro.sparse.shard).
# --------------------------------------------------------------------------

def nnz_balanced_splits(weights, num_shards: int, *,
                        align: int = 1) -> np.ndarray:
    """Contiguous split points balancing a weight vector across shards.

    The prefix-sum splitter behind the sharded tier: given per-item
    weights (nnz per row for CSR/ELL/BCSR row blocks, nnz per column for
    the reduce-scatter column partition, nnz per diagonal for DIA band
    shards), pick ``num_shards - 1`` cut points so every contiguous chunk
    carries ~``total / num_shards`` weight.  Each cut lands on the
    aligned position whose prefix sum is closest to its ideal target, so
    the imbalance of any shard is bounded by the heaviest aligned group
    of items — for BCSR pass ``align=t`` to keep row blocks intact.

    Args:
        weights: per-item nonnegative weights, length ``n``.
        num_shards: number of contiguous chunks (>= 1).
        align: cut points are restricted to multiples of this (``n`` must
            divide by it).

    Returns:
        Monotone int64 bounds of shape ``[num_shards + 1]`` with
        ``bounds[0] == 0`` and ``bounds[-1] == n``; shard ``i`` owns
        items ``[bounds[i], bounds[i+1])``.

    Raises:
        ValueError: on ``num_shards < 1``, ``align < 1``, or ``n`` not a
            multiple of ``align``.
    """
    counts = np.asarray(weights, dtype=np.int64).ravel()
    n = counts.shape[0]
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    if n % align != 0:
        raise ValueError(f"{n} items not divisible by align={align}")
    csum = np.concatenate([[0], np.cumsum(counts)])
    cand = np.arange(0, n + 1, align)          # aligned cut positions
    targets = csum[-1] * np.arange(1, num_shards) / num_shards
    pos = np.clip(np.searchsorted(csum[cand], targets), 1, cand.size - 1)
    left, right = cand[pos - 1], cand[pos]
    pick = np.where(targets - csum[left] <= csum[right] - targets,
                    left, right)
    bounds = np.concatenate([[0], pick, [n]])
    return np.maximum.accumulate(bounds).astype(np.int64)


def coo_to_dense(m, dtype=jnp.float32) -> jnp.ndarray:
    """Materialize the full dense [n, n] array (reference/tests only)."""
    dense = np.zeros((m.n, m.n), dtype=dtype)
    dense[m.rows, m.cols] = m.vals.astype(dtype)
    return jnp.asarray(dense)
