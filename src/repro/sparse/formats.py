"""Sparse matrix containers as JAX pytrees (CSR / ELL / BCSR / DIA).

These mirror the paper's evaluated layouts:

  CSR    row-pointer format — the paper's baseline implementation.
  ELL    padded row format — stands in for the vendor (MKL-style) kernel:
         fully vectorizable, wasteful on skewed rows.
  BCSR   dense t x t blocks with block-CSR indexing — the TPU adaptation of
         the paper's CSB (Compressed Sparse Blocks): every nonzero block is
         stored densely so the MXU can consume it directly.
  DIA    banded/diagonal storage — realizes the paper's diagonal regime.

All arrays are jnp; static shape information (n, t, nnz) lives in aux data so
the containers jit cleanly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _register(cls, data_fields: Tuple[str, ...], meta_fields: Tuple[str, ...]):
    jax.tree_util.register_dataclass(cls, list(data_fields), list(meta_fields))
    return cls


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """CSR with a precomputed per-nonzero row-id vector (segment ids)."""

    data: jnp.ndarray      # [nnz] values
    indices: jnp.ndarray   # [nnz] column ids (int32)
    indptr: jnp.ndarray    # [n+1] row pointers (int32)
    row_ids: jnp.ndarray   # [nnz] row id per nonzero (int32)
    n: int                 # static

    @property
    def nnz(self) -> int:
        """Number of stored nonzeros."""
        return int(self.data.shape[0])


_register(CSRMatrix, ("data", "indices", "indptr", "row_ids"), ("n",))


@dataclasses.dataclass(frozen=True)
class ELLMatrix:
    """Padded (ELLPACK) layout: fixed nonzeros-per-row with a validity mask."""

    data: jnp.ndarray      # [n, k] values, zero-padded
    indices: jnp.ndarray   # [n, k] column ids, padded with 0
    n: int                 # static

    @property
    def k(self) -> int:
        """Padded slots per row (the max row degree at conversion time)."""
        return int(self.data.shape[1])


_register(ELLMatrix, ("data", "indices"), ("n",))


@dataclasses.dataclass(frozen=True)
class BCSRMatrix:
    """Block-CSR with dense t x t blocks (TPU CSB analogue).

    ``block_rows``/``block_cols`` are per-nonzero-block coordinates in block
    space; blocks are sorted by (block_row, block_col) so a block row is a
    contiguous slice — the Pallas kernel walks ``block_ptr`` like CSR walks
    ``indptr``.
    """

    blocks: jnp.ndarray      # [N, t, t] dense block values
    block_rows: jnp.ndarray  # [N] block-row id (int32)
    block_cols: jnp.ndarray  # [N] block-col id (int32)
    block_ptr: jnp.ndarray   # [nb+1] first block of each block row (int32)
    n: int                   # static: matrix dimension
    t: int                   # static: block edge
    nnz: int                 # static: true nonzeros (for FLOP accounting)

    @property
    def num_blocks(self) -> int:
        """Count of stored (nonzero) t x t blocks — the paper's N."""
        return int(self.blocks.shape[0])

    @property
    def nb(self) -> int:
        """Number of block rows/cols (n / t)."""
        return self.n // self.t


_register(BCSRMatrix, ("blocks", "block_rows", "block_cols", "block_ptr"),
          ("n", "t", "nnz"))


@dataclasses.dataclass(frozen=True)
class DIAMatrix:
    """Diagonal storage: one row of values per stored offset."""

    data: jnp.ndarray      # [num_offsets, n] values (zero where out of band)
    offsets: Tuple[int, ...]  # static diagonal offsets
    n: int                 # static

    @property
    def num_offsets(self) -> int:
        """Number of stored diagonals."""
        return int(self.data.shape[0])


_register(DIAMatrix, ("data",), ("offsets", "n"))


# --------------------------------------------------------------------------
# Converters from the numpy COO patterns (repro.core.patterns.COOMatrix).
# --------------------------------------------------------------------------

def coo_to_csr(m, dtype=jnp.float32) -> CSRMatrix:
    """Convert a COO pattern to CSR.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.

    Returns:
        :class:`CSRMatrix` with row-major-sorted ``data``/``indices``
        ([nnz]), ``indptr`` ([n+1]), and precomputed ``row_ids`` ([nnz]).
    """
    order = np.lexsort((m.cols, m.rows))
    rows = m.rows[order]
    cols = m.cols[order]
    vals = m.vals[order].astype(dtype)
    counts = np.bincount(rows, minlength=m.n)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return CSRMatrix(
        data=jnp.asarray(vals),
        indices=jnp.asarray(cols.astype(np.int32)),
        indptr=jnp.asarray(indptr),
        row_ids=jnp.asarray(rows.astype(np.int32)),
        n=m.n,
    )


def coo_to_ell(m, dtype=jnp.float32, max_k: int | None = None) -> ELLMatrix:
    """Convert a COO pattern to padded ELLPACK.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.
        max_k: cap on slots per row; defaults to the max row degree.
            Entries beyond the cap are dropped (callers gate on padding
            blow-up before choosing ELL).

    Returns:
        :class:`ELLMatrix` with zero-padded ``data``/``indices`` [n, k].
    """
    counts = np.bincount(m.rows, minlength=m.n)
    k = int(counts.max()) if max_k is None else max_k
    k = max(k, 1)
    data = np.zeros((m.n, k), dtype=dtype)
    indices = np.zeros((m.n, k), dtype=np.int32)
    slot = np.zeros(m.n, dtype=np.int64)
    order = np.lexsort((m.cols, m.rows))
    for r, c, v in zip(m.rows[order], m.cols[order], m.vals[order]):
        s = slot[r]
        if s < k:
            data[r, s] = v
            indices[r, s] = c
            slot[r] = s + 1
    return ELLMatrix(data=jnp.asarray(data), indices=jnp.asarray(indices),
                     n=m.n)


def coo_to_bcsr(m, t: int, dtype=jnp.float32) -> BCSRMatrix:
    """Convert a COO pattern to dense-block BCSR.

    Args:
        m: ``repro.core.patterns.COOMatrix``; ``m.n`` must divide by ``t``.
        t: block edge (t x t dense blocks).
        dtype: value dtype of the blocks.

    Returns:
        :class:`BCSRMatrix` with ``blocks`` [N, t, t] sorted by
        (block_row, block_col) and CSR-style ``block_ptr`` [nb+1].

    Raises:
        ValueError: if ``m.n`` is not a multiple of ``t``.
    """
    if m.n % t != 0:
        raise ValueError(f"matrix dim {m.n} not divisible by block size {t}")
    bi = m.rows.astype(np.int64) // t
    bj = m.cols.astype(np.int64) // t
    nb = m.n // t
    blin = bi * nb + bj
    uniq, inverse = np.unique(blin, return_inverse=True)
    N = uniq.shape[0]
    blocks = np.zeros((N, t, t), dtype=dtype)
    rr = m.rows % t
    cc = m.cols % t
    blocks[inverse, rr, cc] = m.vals.astype(dtype)
    block_rows = (uniq // nb).astype(np.int32)
    block_cols = (uniq % nb).astype(np.int32)
    counts = np.bincount(block_rows, minlength=nb)
    block_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return BCSRMatrix(
        blocks=jnp.asarray(blocks),
        block_rows=jnp.asarray(block_rows),
        block_cols=jnp.asarray(block_cols),
        block_ptr=jnp.asarray(block_ptr),
        n=m.n, t=t, nnz=m.nnz,
    )


def coo_to_dia(m, dtype=jnp.float32, max_offsets: int = 64) -> DIAMatrix:
    """Convert a COO pattern to diagonal (DIA) storage.

    Args:
        m: ``repro.core.patterns.COOMatrix`` (square, [n, n]).
        dtype: value dtype of the container.
        max_offsets: refuse matrices with more distinct diagonals than
            this (DIA storage is k*n values; only banded matrices fit).

    Returns:
        :class:`DIAMatrix` with ``data`` [num_offsets, n] indexed by row.

    Raises:
        ValueError: if the matrix has more than ``max_offsets`` diagonals.
    """
    offs = np.unique(m.cols.astype(np.int64) - m.rows)
    if offs.shape[0] > max_offsets:
        raise ValueError(
            f"{offs.shape[0]} distinct diagonals exceeds max_offsets="
            f"{max_offsets}; DIA only suits banded matrices")
    data = np.zeros((offs.shape[0], m.n), dtype=dtype)
    off_index = {int(o): i for i, o in enumerate(offs)}
    for r, c, v in zip(m.rows, m.cols, m.vals):
        data[off_index[int(c) - int(r)], r] = v
    return DIAMatrix(data=jnp.asarray(data),
                     offsets=tuple(int(o) for o in offs), n=m.n)


# --------------------------------------------------------------------------
# Shard splitters (consumed by repro.sparse.shard).
# --------------------------------------------------------------------------

def nnz_balanced_splits(weights, num_shards: int, *,
                        align: int = 1) -> np.ndarray:
    """Contiguous split points balancing a weight vector across shards.

    The prefix-sum splitter behind the sharded tier: given per-item
    weights (nnz per row for CSR/ELL/BCSR row blocks, nnz per column for
    the reduce-scatter column partition, nnz per diagonal for DIA band
    shards), pick ``num_shards - 1`` cut points so every contiguous chunk
    carries ~``total / num_shards`` weight.  Each cut lands on the
    aligned position whose prefix sum is closest to its ideal target, so
    the imbalance of any shard is bounded by the heaviest aligned group
    of items — for BCSR pass ``align=t`` to keep row blocks intact.

    Args:
        weights: per-item nonnegative weights, length ``n``.
        num_shards: number of contiguous chunks (>= 1).
        align: cut points are restricted to multiples of this (``n`` must
            divide by it).

    Returns:
        Monotone int64 bounds of shape ``[num_shards + 1]`` with
        ``bounds[0] == 0`` and ``bounds[-1] == n``; shard ``i`` owns
        items ``[bounds[i], bounds[i+1])``.

    Raises:
        ValueError: on ``num_shards < 1``, ``align < 1``, or ``n`` not a
            multiple of ``align``.
    """
    counts = np.asarray(weights, dtype=np.int64).ravel()
    n = counts.shape[0]
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    if n % align != 0:
        raise ValueError(f"{n} items not divisible by align={align}")
    csum = np.concatenate([[0], np.cumsum(counts)])
    cand = np.arange(0, n + 1, align)          # aligned cut positions
    targets = csum[-1] * np.arange(1, num_shards) / num_shards
    pos = np.clip(np.searchsorted(csum[cand], targets), 1, cand.size - 1)
    left, right = cand[pos - 1], cand[pos]
    pick = np.where(targets - csum[left] <= csum[right] - targets,
                    left, right)
    bounds = np.concatenate([[0], pick, [n]])
    return np.maximum.accumulate(bounds).astype(np.int64)


def coo_to_dense(m, dtype=jnp.float32) -> jnp.ndarray:
    """Materialize the full dense [n, n] array (reference/tests only)."""
    dense = np.zeros((m.n, m.n), dtype=dtype)
    dense[m.rows, m.cols] = m.vals.astype(dtype)
    return jnp.asarray(dense)
