"""Sparse formats + SpMM implementations (CSR / ELL / BCSR / DIA)."""
from repro.sparse.formats import (
    BCSRMatrix, CSRMatrix, DIAMatrix, ELLMatrix,
    coo_to_bcsr, coo_to_csr, coo_to_dense, coo_to_dia, coo_to_ell,
)
from repro.sparse.spmm import (
    IMPLEMENTATIONS, bcsr_spmm, bcsr_spmm_scan, csr_spmm, dense_spmm,
    dia_spmm, ell_spmm,
)

__all__ = [
    "BCSRMatrix", "CSRMatrix", "DIAMatrix", "ELLMatrix",
    "coo_to_bcsr", "coo_to_csr", "coo_to_dense", "coo_to_dia", "coo_to_ell",
    "IMPLEMENTATIONS", "bcsr_spmm", "bcsr_spmm_scan", "csr_spmm",
    "dense_spmm", "dia_spmm", "ell_spmm",
]
