"""Sparse formats, SpMM implementations, and the structure-aware dispatcher.

``spmm(m, b, strategy="auto")`` is the public entry point: it classifies
the matrix, evaluates each format's sparsity-aware roofline on the active
hardware, and runs the winning (format, kernel) pair.  The per-format
implementations remain exported for direct use.
"""
from repro.sparse.formats import (
    BCSRMatrix, CSRMatrix, DIAMatrix, ELLMatrix,
    coo_to_bcsr, coo_to_csr, coo_to_dense, coo_to_dia, coo_to_ell,
)
from repro.sparse.spmm import (
    IMPLEMENTATIONS, bcsr_spmm, bcsr_spmm_scan, csr_spmm, dense_spmm,
    dia_spmm, ell_spmm,
)
from repro.sparse.dispatch import (
    DispatchPlan, Dispatcher, FORMATS, STRATEGIES, plan_spmm, spmm,
)

__all__ = [
    "BCSRMatrix", "CSRMatrix", "DIAMatrix", "ELLMatrix",
    "coo_to_bcsr", "coo_to_csr", "coo_to_dense", "coo_to_dia", "coo_to_ell",
    "IMPLEMENTATIONS", "bcsr_spmm", "bcsr_spmm_scan", "csr_spmm",
    "dense_spmm", "dia_spmm", "ell_spmm",
    "DispatchPlan", "Dispatcher", "FORMATS", "STRATEGIES", "plan_spmm",
    "spmm",
]
