"""Sparse formats, SpMM implementations, dispatcher, and the streaming layer.

``spmm(m, b, strategy="auto")`` is the one-shot public entry point: it
classifies the matrix, evaluates each format's sparsity-aware roofline on
the active hardware, and runs the winning (format, kernel) pair.

``plan(m, b_spec)`` is the serving entry point: it runs classification,
roofline prediction, and format conversion once, then ``plan.execute(b)``
/ ``plan.execute_many(bs)`` replay the bound kernel across many dense
right-hand sides (``docs/serving.md``).  The per-format implementations
remain exported for direct use.
"""
from repro.sparse.formats import (
    BCSRMatrix, BinnedMatrix, CSRMatrix, DEFAULT_PRECISION, DIAMatrix,
    ELLCOOMatrix, ELLMatrix, INT16_MAX_EXTENT, PRECISION_BF16,
    PRECISION_BF16_I32, PRECISION_FP32, PRECISIONS, Precision,
    RowSplitMatrix, as_precision,
    coo_to_bcsr, coo_to_binned, coo_to_csr, coo_to_dense, coo_to_dia,
    coo_to_ell, coo_to_ell_coo, coo_to_rowsplit, ell_coo_cutoff,
    int16_extent_ok, nnz_balanced_splits,
)
from repro.sparse.spmm import (
    IMPLEMENTATIONS, bcsr_spmm, bcsr_spmm_scan, binned_spmm, csr_spmm,
    dense_spmm, dia_spmm, ell_coo_spmm, ell_spmm, rowsplit_spmm,
)
from repro.sparse.dispatch import (
    DispatchPlan, Dispatcher, FORMATS, STRATEGIES, default_dispatcher,
    plan_spmm, spmm,
)
from repro.sparse.stream import BSpec, StreamPlan, as_b_spec, plan
from repro.sparse.shard import (
    B_STRATEGIES, ShardedPlan, ShardStrategyEval,
)
from repro.sparse.engine import (
    BatchRecord, ServingEngine, ShedError, Ticket, coalesce_budget,
)

__all__ = [
    "BCSRMatrix", "BinnedMatrix", "CSRMatrix", "DIAMatrix", "ELLCOOMatrix",
    "ELLMatrix", "RowSplitMatrix",
    "coo_to_bcsr", "coo_to_binned", "coo_to_csr", "coo_to_dense",
    "coo_to_dia", "coo_to_ell", "coo_to_ell_coo", "coo_to_rowsplit",
    "ell_coo_cutoff", "nnz_balanced_splits",
    "Precision", "PRECISIONS", "PRECISION_FP32", "PRECISION_BF16",
    "PRECISION_BF16_I32", "DEFAULT_PRECISION", "INT16_MAX_EXTENT",
    "as_precision", "int16_extent_ok",
    "IMPLEMENTATIONS", "bcsr_spmm", "bcsr_spmm_scan", "binned_spmm",
    "csr_spmm", "dense_spmm", "dia_spmm", "ell_coo_spmm", "ell_spmm",
    "rowsplit_spmm",
    "DispatchPlan", "Dispatcher", "FORMATS", "STRATEGIES",
    "default_dispatcher", "plan_spmm", "spmm",
    "BSpec", "StreamPlan", "as_b_spec", "plan",
    "B_STRATEGIES", "ShardedPlan", "ShardStrategyEval",
    "BatchRecord", "ServingEngine", "ShedError", "Ticket",
    "coalesce_budget",
]
