"""Sharded SpMM execution tier: mesh-partitioned plans under ``shard_map``.

The paper's thesis — attainable SpMM is set by sparsity structure, not one
roofline — extends to the multi-device regime: once the sparse operand is
partitioned across a mesh, the binding resource per shard can flip between
DRAM bandwidth, the format compute ceiling, and interconnect/collective
traffic.  This module is that regime's dispatch layer:

    mesh = make_shard_mesh(8)                    # repro.launch.mesh
    plan = sparse.plan(m, BSpec(d=64), mesh=mesh)   # -> ShardedPlan
    c = plan.execute(b)                          # shard_map replay
    print(plan.summary())                        # format + B-strategy audit

Partitioning follows structure, exactly like format choice does:

  * CSR / ELL / BCSR take **contiguous row-block shards**, balanced by
    nnz (not rows) via the prefix-sum splitter
    ``repro.sparse.formats.nnz_balanced_splits`` (BCSR cuts align to the
    block edge t); the reduce-scatter strategy instead partitions by
    **columns** so each shard owns a slice of B and bins its partial
    products by destination row block before reducing — the
    propagation-blocking formulation (arXiv 2002.11302).
  * DIA takes **diagonal-band shards**: contiguous runs of diagonals,
    balanced by per-diagonal nnz.  Every band shard produces a
    full-height partial C, reduced across the mesh.

The dispatcher itself picks the B-distribution strategy per plan —
``replicate`` (broadcast B, row-sharded A and C), ``all_gather``
(row-sharded B gathered in-kernel; composes with an already-sharded
serving pipeline), or ``reduce_scatter`` (column-sharded A, local B
slice, partial C reduce-scattered) — scoring each like a format
candidate: per-shard sparsity-aware AI on the critical (most loaded)
shard plus the strategy's collective cost
(``repro.core.roofline.collective_time`` over
``HardwareSpec.collective_bandwidth``), with skip/selection reasons
recorded in :meth:`ShardedPlan.summary`.

Execution runs under ``jax.experimental.shard_map`` over a 1-D flattening
of the caller's mesh, reusing the registry's jax-backend
``KernelSpec.run`` unchanged inside each shard for CSR/ELL/BCSR (padded
per-shard layouts are stacked on a leading device axis).  DIA is the one
exception: its registered kernel unrolls *static* per-matrix offsets, so
heterogeneous band shards use a traced-offset gather body instead.  The
CPU-verifiable path is 8 virtual host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import sparsity_models as sm
from repro.core.patterns import COOMatrix
from repro.core.precision import Precision, as_precision
from repro.core.roofline import ShardRoofline, collective_time
from repro.sparse import formats as fmt
from repro.sparse import stream as _stream

#: The B-distribution strategies the sharded dispatcher scores.
B_STRATEGIES: Tuple[str, ...] = ("replicate", "all_gather", "reduce_scatter")

#: Mesh axis name the sharded tier executes over (the caller's mesh is
#: flattened to one dimension of this name).
SHARD_AXIS = "shard"


@dataclasses.dataclass(frozen=True)
class ShardStrategyEval:
    """One B-distribution strategy's audit record inside a ShardedPlan.

    Mirrors ``repro.sparse.dispatch.CandidateEval`` one level up: the
    dispatcher scores every strategy, keeps the losers' predictions, and
    records a skip reason for the ineligible ones.
    """

    strategy: str                     # one of B_STRATEGIES
    partition: str                    # "row-block" | "column-block" | "diagonal-band"
    eligible: bool
    skip_reason: Optional[str]        # None when eligible
    roofline: Optional[ShardRoofline]  # per-shard AI + collective cost

    @property
    def predicted_gflops(self) -> Optional[float]:
        """Whole-matrix useful GFLOP/s the cost model predicts."""
        if self.roofline is None:
            return None
        return self.roofline.predicted_flops_per_s / 1e9


def _pick_strategy(evals, requested: str) -> str:
    """Resolve the winning strategy ("auto" = best predicted GFLOP/s)."""
    if requested != "auto":
        ev = next(e for e in evals if e.strategy == requested)
        if not ev.eligible:
            raise ValueError(
                f"b_strategy {requested!r} is ineligible for this plan: "
                f"{ev.skip_reason}")
        return requested
    viable = [e for e in evals if e.eligible and e.roofline is not None]
    return max(viable, key=lambda e: e.roofline.predicted_flops_per_s
               ).strategy


class ShardedPlan(_stream.StreamPlan):
    """A StreamPlan whose replay runs SPMD over a device mesh.

    Construction extends the single-device pipeline with three sharded
    phases: partition the chosen format's operand per structure, score
    the three B-distribution strategies with the communication-aware
    roofline, and compile one ``shard_map`` closure for the winner.  The
    inherited ``execute`` / ``execute_many`` / ``execute_wide`` then
    replay that closure — the serving path composes unchanged.

    Attributes:
        mesh: the 1-D execution mesh (caller's mesh flattened).
        num_shards: mesh size D.
        b_strategy: the chosen B-distribution strategy.
        partition: the chosen strategy's partitioning scheme.
        strategy_evals: per-strategy audit records (predictions + skip
            reasons), rendered by :meth:`summary`.
        shard_nnz: nonzeros per shard under the chosen partition.
    """

    def __init__(self, dispatcher, m: COOMatrix, spec, mesh, *,
                 strategy: str = "auto", b_strategy: str = "auto"):
        """Plan, score strategies, and bind the shard_map executor.

        Args:
            dispatcher: the ``repro.sparse.dispatch.Dispatcher`` owning
                caches and the hardware model.
            m: square sparse pattern, ``[n, n]``.
            spec: the stream description (``BSpec``).
            mesh: any ``jax`` mesh (e.g. from ``repro.launch.mesh``);
                its devices are flattened to one ``"shard"`` axis.
            strategy: ``"auto"`` or a forced *format* name.
            b_strategy: ``"auto"`` or a forced B-distribution strategy
                from ``B_STRATEGIES``.

        Raises:
            ValueError: on an unknown or ineligible ``b_strategy``.
        """
        if b_strategy not in ("auto",) + B_STRATEGIES:
            raise ValueError(f"unknown b_strategy {b_strategy!r}; choose "
                             f"from {('auto',) + B_STRATEGIES}")
        devices = np.asarray(mesh.devices).reshape(-1)
        self.mesh = Mesh(devices, (SHARD_AXIS,))
        self.num_shards = int(devices.size)
        self._b_strategy_req = b_strategy
        super().__init__(dispatcher, m, spec, strategy=strategy)

    def _exec_precision(self) -> Precision:
        """The precision the per-shard kernels actually pack and run at.

        Values follow the plan's precision; indices are pinned to int32
        because the sharded tier executes jax-backend kernels inside each
        shard (XLA gathers take int32), so a ``bf16i16`` plan executes as
        ``bf16i32`` here — same value traffic, wider indices.
        """
        prec = as_precision(self.dispatch.precision)
        if prec.index_dtype != "int32":
            prec = Precision(prec.value_dtype, "int32")
        return prec

    # ------------------------------------------------------------- #
    # Planning: strategy scoring
    # ------------------------------------------------------------- #

    def _bind(self) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Score B-strategies and compile the winner's shard_map closure."""
        disp, m, plan = self._dispatcher, self._m, self.dispatch
        fmt_name, d, n, nnz = plan.chosen, plan.d, m.n, max(m.nnz, 1)
        D = self.num_shards
        hw = disp._resolve_hardware(plan.backend)
        prec = self._exec_precision()
        sv = prec.sizeof_val
        cand = plan.candidate(fmt_name)
        ceiling = disp._ceiling(fmt_name, hw, plan.backend,
                                plan.precision).attainable(
            hw.peak_flops, cand.useful_fraction or 1.0, d)
        flops = sm.flops_spmm(nnz, d)
        S = float(n * d * sv)                 # one full B or C buffer

        if fmt_name == "dia":
            dia = disp.convert(m, "dia", precision=prec)
            diag_nnz = np.count_nonzero(np.asarray(dia.data), axis=1)
            band_bounds = fmt.nnz_balanced_splits(diag_nnz, D)
            full_tb = sm.TrafficBreakdown(
                flops=flops, bytes_a=dia.num_offsets * n * sv,
                bytes_b=S, bytes_c=S, model="diagonal")
            partitions = {
                "replicate": ("diagonal-band", band_bounds, diag_nnz),
                "reduce_scatter": ("diagonal-band", band_bounds, diag_nnz),
            }
            comm = {"replicate": (S + 2 * (D - 1) / D * S, 2),
                    "reduce_scatter": (S + 2 * (D - 1) / D * S, 3)}
            skip = {"all_gather": (
                "diagonal-band shards read essentially every row of B; "
                "all-gathering a row shard reconstructs the replicate "
                "broadcast with extra latency")}
        else:
            align = disp.bcsr_block if fmt_name == "bcsr" else 1
            row_nnz = np.bincount(m.rows, minlength=n)
            col_nnz = np.bincount(m.cols, minlength=n)
            row_bounds = fmt.nnz_balanced_splits(row_nnz, D, align=align)
            col_bounds = fmt.nnz_balanced_splits(col_nnz, D, align=align)
            bytes_c = S
            total_bytes = flops / cand.ai if cand.ai else bytes_c
            full_tb = sm.TrafficBreakdown(
                flops=flops, bytes_a=max(total_bytes - bytes_c, 0.0),
                bytes_b=0.0, bytes_c=bytes_c, model=plan.regime)
            partitions = {
                "replicate": ("row-block", row_bounds, row_nnz),
                "all_gather": ("row-block", row_bounds, row_nnz),
                "reduce_scatter": ("column-block", col_bounds, col_nnz),
            }
            comm = {"replicate": (S + (D - 1) / D * S, 2),
                    "all_gather": (2 * (D - 1) / D * S, 2),
                    "reduce_scatter": (S / D + 2 * (D - 1) / D * S, 3)}
            skip = {}

        evals = []
        for name in B_STRATEGIES:
            if name in skip:
                evals.append(ShardStrategyEval(
                    strategy=name, partition="-", eligible=False,
                    skip_reason=skip[name], roofline=None))
                continue
            part, bounds, weights = partitions[name]
            shard_nnz = np.add.reduceat(
                weights, bounds[:-1])[:D] if weights.size else np.zeros(D)
            # Guard reduceat's empty-slice quirk (repeated bounds repeat
            # the next value instead of 0).
            shard_nnz = np.where(np.diff(bounds) > 0, shard_nnz, 0)
            worst = ai_crit = fl_crit = 0.0
            for i in range(D):
                frac = shard_nnz[i] / nnz
                if frac <= 0:
                    continue
                rows_frac = ((bounds[i + 1] - bounds[i]) / n
                             if part == "row-block" else 1.0)
                tb_i = sm.shard_traffic(
                    full_tb, nnz_fraction=frac, rows_fraction=rows_frac,
                    bytes_b=S if part == "diagonal-band" else None)
                pred_i = min(hw.hbm_bandwidth * tb_i.ai, ceiling)
                t_i = tb_i.flops / pred_i if pred_i > 0 else 0.0
                if t_i >= worst:
                    worst, ai_crit, fl_crit = t_i, tb_i.ai, tb_i.flops
            bytes_wire, n_coll = comm[name]
            roof = ShardRoofline(
                strategy=name, devices=D, shard_ai=ai_crit,
                critical_flops=fl_crit, total_flops=flops,
                compute_s=worst,
                collective_s=collective_time(bytes_wire, hw, D,
                                             collectives=n_coll),
                collective_bytes=bytes_wire if D > 1 else 0.0)
            evals.append(ShardStrategyEval(
                strategy=name, partition=part, eligible=True,
                skip_reason=None, roofline=roof))

        self.strategy_evals = tuple(evals)
        self.b_strategy = _pick_strategy(evals, self._b_strategy_req)
        chosen_ev = next(e for e in evals if e.strategy == self.b_strategy)
        self.partition = chosen_ev.partition
        part, bounds, weights = (partitions[self.b_strategy]
                                 if self.b_strategy in partitions else
                                 partitions["replicate"])
        self.shard_bounds = np.asarray(bounds)
        counts = np.add.reduceat(weights, bounds[:-1])[:D] \
            if weights.size else np.zeros(D, dtype=np.int64)
        self.shard_nnz = np.where(np.diff(bounds) > 0, counts, 0)
        return self._build_executor(fmt_name, bounds)

    # ------------------------------------------------------------- #
    # Execution: shard_map closures
    # ------------------------------------------------------------- #

    def _kernel_ctx(self):
        """KernelContext for the per-shard jax-backend KernelSpec.run."""
        from repro.kernels import registry
        disp, plan = self._dispatcher, self.dispatch
        prec = self._exec_precision()

        def _convert(mm, format, _prec=prec):
            return disp.convert(mm, format, precision=_prec)

        return registry.KernelContext(
            hardware=disp._resolve_hardware(plan.backend),
            bcsr_block=disp.bcsr_block,
            max_dia_offsets=disp.max_dia_offsets,
            plan_d=plan.d, precision=prec, convert=_convert)

    def _build_executor(self, fmt_name: str, bounds: np.ndarray):
        """Pack per-shard layouts and compile the strategy's closure.

        The sharded tier always executes the *jax*-backend KernelSpec
        inside each shard: its layouts are plain stacked arrays, so D
        padded shard layouts concatenate on a leading device axis and
        flow through ``shard_map`` untouched.  (The pallas row-tile
        packings are host-side ragged structures; sharding them is a
        ROADMAP follow-up.)
        """
        if fmt_name == "dia":
            return self._bind_dia(bounds)
        if fmt_name in ("binned", "rowsplit", "ell_coo"):
            # CSR-equivalent gather layouts (the scale-free tier): their
            # host-side orderings are whole-matrix properties that do not
            # survive row/column slicing, so per-shard execution reuses
            # the CSR packing and the jax CSR kernel inside each shard.
            fmt_name = "csr"
        if self.b_strategy == "reduce_scatter":
            return self._bind_cols(fmt_name, bounds)
        return self._bind_rows(fmt_name, bounds)

    def _bind_rows(self, fmt_name: str, bounds: np.ndarray):
        """Row-block execution: replicate-B or all-gather-B."""
        from repro.kernels import registry
        disp, m = self._dispatcher, self._m
        mesh, D, n = self.mesh, self.num_shards, self._m.n
        spec_k = registry.get(fmt_name, "jax")
        ctx = self._kernel_ctx()
        prec = self._exec_precision()
        rows_per = np.diff(bounds)
        R = int(max(rows_per.max(), 1))

        if fmt_name == "csr":
            csr = disp.convert(m, "csr", precision=prec)
            indptr = np.asarray(csr.indptr)
            data, idx, rid = (np.asarray(csr.data), np.asarray(csr.indices),
                              np.asarray(csr.row_ids))
            nnz_per = indptr[bounds[1:]] - indptr[bounds[:-1]]
            NNZ = int(max(nnz_per.max(), 1))
            d_s = np.zeros((D, NNZ), data.dtype)
            i_s = np.zeros((D, NNZ), np.int32)
            r_s = np.zeros((D, NNZ), np.int32)
            for i in range(D):
                lo, hi = indptr[bounds[i]], indptr[bounds[i + 1]]
                k = hi - lo
                d_s[i, :k] = data[lo:hi]
                i_s[i, :k] = idx[lo:hi]
                r_s[i, :k] = rid[lo:hi] - bounds[i]
            arrs = tuple(jnp.asarray(a) for a in (d_s, i_s, r_s))

            def local(arrs, b_full):
                a_loc = fmt.CSRMatrix(
                    data=arrs[0][0], indices=arrs[1][0],
                    indptr=jnp.zeros(R + 1, jnp.int32),
                    row_ids=arrs[2][0], n=R)
                return spec_k.run(a_loc, b_full, ctx)

        elif fmt_name == "ell":
            ell = disp.convert(m, "ell", precision=prec)
            data, idx = np.asarray(ell.data), np.asarray(ell.indices)
            k = data.shape[1]
            d_s = np.zeros((D, R, k), data.dtype)
            i_s = np.zeros((D, R, k), np.int32)
            for i in range(D):
                r = rows_per[i]
                d_s[i, :r] = data[bounds[i]:bounds[i + 1]]
                i_s[i, :r] = idx[bounds[i]:bounds[i + 1]]
            arrs = (jnp.asarray(d_s), jnp.asarray(i_s))

            def local(arrs, b_full):
                a_loc = fmt.ELLMatrix(data=arrs[0][0], indices=arrs[1][0],
                                      n=R)
                return spec_k.run(a_loc, b_full, ctx)

        else:                               # bcsr
            bcsr = disp.convert(m, "bcsr", precision=prec)
            t = bcsr.t
            bptr = np.asarray(bcsr.block_ptr)
            blocks = np.asarray(bcsr.blocks)
            brows, bcols = (np.asarray(bcsr.block_rows),
                            np.asarray(bcsr.block_cols))
            sb = bounds // t
            nblk = bptr[sb[1:]] - bptr[sb[:-1]]
            NB = int(max(nblk.max(), 1))
            bl_s = np.zeros((D, NB, t, t), blocks.dtype)
            br_s = np.zeros((D, NB), np.int32)
            bc_s = np.zeros((D, NB), np.int32)
            for i in range(D):
                lo, hi = bptr[sb[i]], bptr[sb[i + 1]]
                kk = hi - lo
                bl_s[i, :kk] = blocks[lo:hi]
                br_s[i, :kk] = brows[lo:hi] - sb[i]
                bc_s[i, :kk] = bcols[lo:hi]
            arrs = tuple(jnp.asarray(a) for a in (bl_s, br_s, bc_s))
            nnz_static = bcsr.nnz

            def local(arrs, b_full):
                # n stays global: bcsr_spmm tiles B by a.nb = n // t, and
                # B here is the full [n, d] operand.  Localized block
                # rows land the shard's output in rows [0, R).
                a_loc = fmt.BCSRMatrix(
                    blocks=arrs[0][0], block_rows=arrs[1][0],
                    block_cols=arrs[2][0],
                    block_ptr=jnp.zeros(n // t + 1, jnp.int32),
                    n=n, t=t, nnz=nnz_static)
                return spec_k.run(a_loc, b_full, ctx)[:R]

        gidx = jnp.asarray(np.concatenate(
            [i * R + np.arange(rows_per[i]) for i in range(D)]
        ).astype(np.int32))

        if self.b_strategy == "replicate":
            body = shard_map(
                lambda a, b: local(a, b)[None], mesh=mesh,
                in_specs=(P(SHARD_AXIS), P()), out_specs=P(SHARD_AXIS),
                check_rep=False)

            def run_impl(arrs, b):
                return body(arrs, b).reshape(D * R, -1)[gidx]
        else:                               # all_gather
            Rb = -(-n // D)
            body = shard_map(
                lambda a, b: local(
                    a, jax.lax.all_gather(b, SHARD_AXIS, tiled=True)[:n]
                )[None],
                mesh=mesh, in_specs=(P(SHARD_AXIS), P(SHARD_AXIS)),
                out_specs=P(SHARD_AXIS), check_rep=False)

            def run_impl(arrs, b):
                b_pad = jnp.pad(b, ((0, D * Rb - n), (0, 0)))
                return body(arrs, b_pad).reshape(D * R, -1)[gidx]

        jitted = jax.jit(run_impl)
        return lambda b: jitted(arrs, b)

    def _bind_cols(self, fmt_name: str, bounds: np.ndarray):
        """Column-block execution: reduce-scatter-output.

        Each shard owns the nonzeros whose *columns* fall in its slice,
        consumes only its rows of B, and produces a full-height partial
        C; ``psum_scatter`` then bins the partials by destination row
        block and reduces them there — propagation blocking as a
        collective.
        """
        from repro.kernels import registry
        disp, m = self._dispatcher, self._m
        mesh, D, n = self.mesh, self.num_shards, self._m.n
        spec_k = registry.get(fmt_name, "jax")
        ctx = self._kernel_ctx()
        prec = self._exec_precision()
        vdt = prec.value_jnp                # ml_dtypes type doubles as np
        cols_per = np.diff(bounds)
        Rc = int(max(cols_per.max(), 1))
        Rout = -(-n // D)

        if fmt_name == "csr":
            NNZ = 1
            packs = []
            for i in range(D):
                sel = (m.cols >= bounds[i]) & (m.cols < bounds[i + 1])
                packs.append((m.vals[sel].astype(vdt),
                              (m.cols[sel] - bounds[i]).astype(np.int32),
                              m.rows[sel].astype(np.int32)))
                NNZ = max(NNZ, int(sel.sum()))
            d_s = np.zeros((D, NNZ), vdt)
            i_s = np.zeros((D, NNZ), np.int32)
            r_s = np.zeros((D, NNZ), np.int32)
            for i, (v, c, r) in enumerate(packs):
                d_s[i, :v.size], i_s[i, :v.size], r_s[i, :v.size] = v, c, r
            arrs = tuple(jnp.asarray(a) for a in (d_s, i_s, r_s))
            b_rows = Rc

            def local(arrs, b_loc):
                a_loc = fmt.CSRMatrix(
                    data=arrs[0][0], indices=arrs[1][0],
                    indptr=jnp.zeros(n + 1, jnp.int32),
                    row_ids=arrs[2][0], n=n)
                return spec_k.run(a_loc, b_loc, ctx)

        elif fmt_name == "ell":
            locals_ell = []
            K = 1
            for i in range(D):
                sel = (m.cols >= bounds[i]) & (m.cols < bounds[i + 1])
                lm = COOMatrix(n=n, rows=m.rows[sel],
                               cols=(m.cols[sel] - bounds[i]).astype(
                                   np.int32),
                               vals=m.vals[sel], pattern=m.pattern)
                e = fmt.coo_to_ell(lm, dtype=vdt)
                locals_ell.append(e)
                K = max(K, e.k)
            d_s = np.zeros((D, n, K), vdt)
            i_s = np.zeros((D, n, K), np.int32)
            for i, e in enumerate(locals_ell):
                d_s[i, :, :e.k] = np.asarray(e.data)
                i_s[i, :, :e.k] = np.asarray(e.indices)
            arrs = (jnp.asarray(d_s), jnp.asarray(i_s))
            b_rows = Rc

            def local(arrs, b_loc):
                a_loc = fmt.ELLMatrix(data=arrs[0][0], indices=arrs[1][0],
                                      n=n)
                return spec_k.run(a_loc, b_loc, ctx)

        else:                               # bcsr
            bcsr = disp.convert(m, "bcsr", precision=prec)
            t = bcsr.t
            blocks = np.asarray(bcsr.blocks)
            brows, bcols = (np.asarray(bcsr.block_rows),
                            np.asarray(bcsr.block_cols))
            sb = bounds // t
            NB = 1
            packs = []
            for i in range(D):
                sel = (bcols >= sb[i]) & (bcols < sb[i + 1])
                packs.append((blocks[sel], brows[sel], bcols[sel] - sb[i]))
                NB = max(NB, int(sel.sum()))
            bl_s = np.zeros((D, NB, t, t), blocks.dtype)
            br_s = np.zeros((D, NB), np.int32)
            bc_s = np.zeros((D, NB), np.int32)
            for i, (bl, br, bc) in enumerate(packs):
                kk = bl.shape[0]
                bl_s[i, :kk], br_s[i, :kk], bc_s[i, :kk] = bl, br, bc
            arrs = tuple(jnp.asarray(a) for a in (bl_s, br_s, bc_s))
            nnz_static = bcsr.nnz
            # bcsr_spmm tiles B by n // t, so the local B slice is padded
            # to full height; the zero tail multiplies nothing.
            b_rows = n

            def local(arrs, b_loc):
                a_loc = fmt.BCSRMatrix(
                    blocks=arrs[0][0], block_rows=arrs[1][0],
                    block_cols=arrs[2][0],
                    block_ptr=jnp.zeros(n // t + 1, jnp.int32),
                    n=n, t=t, nnz=nnz_static)
                return spec_k.run(a_loc, b_loc, ctx)

        def body_fn(arrs, b_chunks):
            partial = local(arrs, b_chunks[0])          # [n, d]
            partial = jnp.pad(partial, ((0, D * Rout - n), (0, 0)))
            return jax.lax.psum_scatter(partial, SHARD_AXIS,
                                        scatter_dimension=0, tiled=True)

        body = shard_map(body_fn, mesh=mesh,
                         in_specs=(P(SHARD_AXIS), P(SHARD_AXIS)),
                         out_specs=P(SHARD_AXIS), check_rep=False)
        b_lo = [int(x) for x in bounds[:-1]]
        b_hi = [int(x) for x in bounds[1:]]

        def run_impl(arrs, b):
            chunks = jnp.stack([
                jnp.pad(b[lo:hi], ((0, b_rows - (hi - lo)), (0, 0)))
                for lo, hi in zip(b_lo, b_hi)])
            return body(arrs, chunks)[:n]

        jitted = jax.jit(run_impl)
        return lambda b: jitted(arrs, b)

    def _bind_dia(self, bounds: np.ndarray):
        """Diagonal-band execution with traced per-shard offsets.

        The registered DIA kernel unrolls *static* offsets, which cannot
        differ across SPMD shards; the band body instead gathers
        ``B[r + offset]`` with offsets as data (padded diagonals carry
        zero values, so they contribute nothing).
        """
        disp, m = self._dispatcher, self._m
        mesh, D, n = self.mesh, self.num_shards, self._m.n
        dia = disp.convert(m, "dia", precision=self._exec_precision())
        offs = np.asarray(dia.offsets, dtype=np.int32)
        data = np.asarray(dia.data)
        K = int(max(np.diff(bounds).max(), 1))
        off_s = np.zeros((D, K), np.int32)
        dat_s = np.zeros((D, K, n), data.dtype)
        for i in range(D):
            k = bounds[i + 1] - bounds[i]
            off_s[i, :k] = offs[bounds[i]:bounds[i + 1]]
            dat_s[i, :k] = data[bounds[i]:bounds[i + 1]]
        arrs = (jnp.asarray(off_s), jnp.asarray(dat_s))
        r = jnp.arange(n)

        def partial_fn(arrs, b_full):
            offsets, dat = arrs[0][0], arrs[1][0]
            idx = r[None, :] + offsets[:, None]          # [K, n]
            valid = (idx >= 0) & (idx < n)
            g = b_full[jnp.clip(idx, 0, n - 1)]          # [K, n, d]
            # Products round at the storage dtype; the band reduction
            # accumulates in fp32 per the precision contract.
            prod = (dat[..., None] * g).astype(jnp.float32)
            contrib = jnp.where(valid[..., None], prod, 0.0)
            return contrib.sum(0).astype(b_full.dtype)   # [n, d]

        if self.b_strategy == "replicate":
            body = shard_map(
                lambda a, b: jax.lax.psum(partial_fn(a, b), SHARD_AXIS),
                mesh=mesh, in_specs=(P(SHARD_AXIS), P()), out_specs=P(),
                check_rep=False)

            def run_impl(arrs, b):
                return body(arrs, b)
        else:                               # reduce_scatter
            Rout = -(-n // D)

            def body_fn(arrs, b):
                partial = jnp.pad(partial_fn(arrs, b),
                                  ((0, D * Rout - n), (0, 0)))
                return jax.lax.psum_scatter(partial, SHARD_AXIS,
                                            scatter_dimension=0,
                                            tiled=True)

            body = shard_map(body_fn, mesh=mesh,
                             in_specs=(P(SHARD_AXIS), P()),
                             out_specs=P(SHARD_AXIS), check_rep=False)

            def run_impl(arrs, b):
                return body(arrs, b)[:n]

        jitted = jax.jit(run_impl)
        return lambda b: jitted(arrs, b)

    # ------------------------------------------------------------- #
    # Introspection
    # ------------------------------------------------------------- #

    def summary(self) -> str:
        """The format decision table plus the B-strategy audit."""
        single = self.dispatch.candidate(self.chosen).predicted_gflops
        nz = self.shard_nnz[self.shard_nnz > 0]
        imbalance = float(nz.max() / nz.mean()) if nz.size else 1.0
        lines = [self.dispatch.summary(),
                 f"ShardedPlan(devices={self.num_shards}, "
                 f"partition={self.partition}, "
                 f"nnz_imbalance={imbalance:.2f}) -> {self.b_strategy}"]
        for ev in self.strategy_evals:
            mark = "*" if ev.strategy == self.b_strategy else " "
            if ev.roofline is not None:
                r = ev.roofline
                perf = (f"comm={r.collective_bytes / 1e6:7.2f}MB"
                        f"  t_comp={r.compute_s * 1e6:9.1f}us"
                        f"  t_coll={r.collective_s * 1e6:9.1f}us"
                        f"  pred={r.predicted_flops_per_s / 1e9:7.2f} GF/s"
                        f" [{r.dominant}-bound]")
            else:
                perf = "(not modeled)"
            tail = "" if ev.eligible else f"  SKIP: {ev.skip_reason}"
            lines.append(f" {mark} {ev.strategy:14s} {perf}{tail}")
        best = next(e for e in self.strategy_evals
                    if e.strategy == self.b_strategy)
        if single and best.predicted_gflops is not None:
            lines.append(f"   model speedup vs single device: "
                         f"{best.predicted_gflops / single:.2f}x")
        return "\n".join(lines)

    def stats(self) -> dict:
        """StreamPlan stats extended with the sharded decision record."""
        out = super().stats()
        out.update({
            "devices": self.num_shards,
            "b_strategy": self.b_strategy,
            "partition": self.partition,
            # Per-shard kernels run the jax backend (int32 gathers), so a
            # bf16i16 plan executes shards at bf16i32.
            "shard_precision": self._exec_precision().token,
            "shard_nnz": [int(x) for x in self.shard_nnz],
        })
        return out

    def exec_hints(self) -> dict:
        """Engine staging metadata for sharded replay.

        The shard_map program is jitted, so dispatch is asynchronous like
        every other backend — but the operand is re-laid-out inside the
        traced closure (padded, chunked, or all-gathered per strategy),
        so donating the caller's staged buffer never helps: the hints pin
        ``donate_b`` False regardless of the per-shard kernel, and the
        jax-backend spec that actually runs inside each shard is the one
        consulted (``ShardedPlan`` executes jax kernels per shard even
        when the single-device plan resolved pallas).
        """
        from repro.kernels import registry
        spec = registry.get(self.dispatch.chosen, "jax")
        return {"async_dispatch": spec.async_dispatch, "donate_b": False,
                "devices": self.num_shards}

    def coalesce_block_d(self, total_cols: int) -> int:
        """Coalesced replay width for the engine: always the planned d.

        Every distinct operand width compiles a fresh shard_map program
        (the closure is jitted over concrete shapes), so an engine whose
        micro-batches vary in total width would recompile per batch.
        Pinning the block to ``spec.d`` keeps one compiled program serving
        every batch — the engine pads the batch to a multiple of it.
        """
        return self.spec.d

    def replan(self, observed_reuse: int) -> "ShardedPlan":
        """Re-plan at an observed horizon, keeping the mesh (see
        ``StreamPlan.replan``)."""
        if observed_reuse < 1:
            raise ValueError(
                f"observed_reuse must be >= 1, got {observed_reuse}")
        spec = dataclasses.replace(self.spec, reuse=observed_reuse)
        return ShardedPlan(self._dispatcher, self._m, spec, self.mesh,
                           strategy=self._strategy,
                           b_strategy=self._b_strategy_req)
