"""Continuous-batching async serving engine over persistent plans.

``repro.sparse.stream`` replays one plan synchronously: the caller owns
the loop, every ``execute`` serves exactly one right-hand side, and the
host blocks per call.  Production traffic is many concurrent streams with
mixed widths and deadlines — the regime this module serves:

    engine = ServingEngine(max_queue=256, policy="wait")
    engine.register("moe", sparse.plan(m, BSpec(d=64, reuse=4096)))
    engine.start()                        # worker thread
    t = engine.submit("moe", b)           # any thread; bounded queue
    c = t.result()                        # per-request future
    print(engine.summary())               # batches, latency, goodput

The serving loop is four stages, each inspectable in :meth:`ServingEngine
.stats`:

1. **Admission.**  ``submit`` tags each request ``(operator, d,
   deadline)`` and appends it to a bounded queue.  A full queue applies
   the backpressure policy: ``"wait"`` blocks the submitter (optionally
   up to a timeout), ``"shed"`` rejects immediately with
   :class:`ShedError` — load-shedding at admission, before any work is
   sunk into the request.

2. **Micro-batch coalescing.**  The drafting step takes the queue head
   and every other queued request for the *same operator* (FIFO within
   the operator) until the plan's column budget is reached, concatenates
   their right-hand sides column-wise, and replays the whole batch
   through one ``execute_wide`` call.  Columns of B are independent in
   SpMM, so coalescing is exact — and it is itself a bandwidth
   optimization: one launch reads A once for the whole batch where
   per-request replay re-reads it per request (the propagation-blocking
   argument, arXiv 2002.11302, applied at the serving layer).  Batches
   never mix plans, and the per-launch width respects the plan's
   ``coalesce_block_d`` (pallas layouts replay at the planned width their
   B-slab was packed for; jax kernels take the whole batch in one call).

3. **Double-buffered staging.**  Dispatch is asynchronous
   (``KernelSpec.async_dispatch``), so after enqueueing batch *i* the
   engine drafts and stages batch *i+1* — host-side concatenation plus
   ``jax.device_put`` — before blocking on *i*: host transfer overlaps
   device compute.  ``KernelSpec.donate_b`` governs when the staged
   buffer may be dropped (at dispatch when the launch consumes it, at
   materialization otherwise).

4. **Completion + plan swap.**  One ``block_until_ready`` per batch (not
   per request), result columns sliced back per ticket, latencies
   recorded.  Between batches the engine polls
   ``plan.maybe_replan()`` — when a stream has outlived its planned reuse
   horizon the plan is rebuilt at the observed horizon and swapped
   atomically under the queue lock; in-flight batches keep the plan they
   were drafted against.

Latency accounting (the numbers ``stats`` reports): a request's latency
is measured from the ``submit`` call's entry (so backpressure wait is
*included* — it is part of what the client observes) to the completion of
``block_until_ready`` on its batch.  p50/p99 are percentiles over served
requests; goodput counts only requests that met their deadline (all
served requests when no deadline was given), divided by the span from
first admission to last completion.  ``docs/serving_engine.md`` walks
through the methodology.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import as_precision
from repro.sparse.stream import StreamPlan

#: Default cap on the staged host->device buffer per micro-batch, in
#: bytes.  Two batches are in flight under double buffering, so the
#: engine's staging footprint is at most twice this.
DEFAULT_STAGE_BYTES: int = 8 * 2 ** 20

#: Default bounded-queue depth (requests).
DEFAULT_MAX_QUEUE: int = 256


class ShedError(RuntimeError):
    """A request was refused at admission (queue full under ``"shed"``,
    or the ``"wait"`` timeout expired before space opened up)."""


def _stage_dtype(plan: StreamPlan):
    """The dtype batches are staged (and executed) at for ``plan``.

    A reduced-precision plan's kernels would cast B on device anyway, so
    the engine casts at staging instead — halving the host->device bytes
    the double buffering has to hide.  Full-precision plans stage at the
    stream's declared dtype.
    """
    prec = as_precision(plan.dispatch.precision)
    return prec.value_jnp if prec.reduced else plan.spec.dtype


def coalesce_budget(plan: StreamPlan, *,
                    stage_bytes: int = DEFAULT_STAGE_BYTES) -> int:
    """Max total RHS columns one micro-batch may carry for ``plan``.

    Two constraints meet here:

    * the staged operand — ``[n, cols]`` at the plan's staging dtype
      (the reduced value dtype for a bf16 plan, else the stream dtype),
      concatenated on the host and moved in one ``device_put`` — must
      fit the staging budget (double buffering keeps two of these
      alive);
    * the batch replays through ``execute_wide`` at the plan's
      ``coalesce_block_d``, so per-launch kernel tiling (including the
      CSR B-slab packed for ``plan_d``) is unchanged by coalescing — the
      budget never needs to model VMEM, only host staging.

    The result is floored at the planned width (a planned-width request
    must always be servable) and rounded down to a multiple of it when
    possible, so batches split evenly into planned-width launches.

    Args:
        plan: the bound :class:`~repro.sparse.stream.StreamPlan`.
        stage_bytes: staging-buffer budget in bytes.

    Returns:
        The column budget (>= ``plan.spec.d``).
    """
    itemsize = np.dtype(_stage_dtype(plan)).itemsize
    cap = max(int(stage_bytes) // (plan.n * itemsize), 1)
    d = max(plan.spec.d, 1)
    return max(d, (cap // d) * d)


@dataclasses.dataclass
class Ticket:
    """Per-request handle: the future plus the request's audit record.

    Attributes:
        id: admission sequence number (unique per engine).
        operator: the registered plan the request was tagged with.
        d: the request's RHS width (requests of mixed widths coalesce).
        deadline_s: absolute deadline on the engine clock, or None.
        submitted_s: clock at ``submit`` entry (latency starts here —
            backpressure wait counts against the request).
        batched_s: clock when the request was drafted into a micro-batch.
        done_s: clock when its batch finished materializing.
        batch_seq: sequence number of the batch that served it.
    """

    id: int
    operator: str
    d: int
    deadline_s: Optional[float] = None
    submitted_s: float = 0.0
    batched_s: Optional[float] = None
    done_s: Optional[float] = None
    batch_seq: Optional[int] = None
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)
    _result: Optional[jnp.ndarray] = dataclasses.field(
        default=None, repr=False)
    _error: Optional[BaseException] = dataclasses.field(
        default=None, repr=False)

    def done(self) -> bool:
        """Whether the request finished (result or error is available)."""
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until served and return this request's ``[n, d]`` result.

        The value is a host-side array (a view into its batch's
        materialized output): a serving engine's responses leave the
        device anyway, and host slicing is what keeps mixed-width
        batches from paying one compiled-slice program per ticket.

        Args:
            timeout: seconds to wait; None waits forever.

        Raises:
            TimeoutError: the request did not complete in time.
            BaseException: whatever the execution raised, re-raised here.
        """
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.id} ({self.operator}, d={self.d}) not "
                f"served within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency_s(self) -> Optional[float]:
        """submit-to-completion latency; None until served."""
        if self.done_s is None:
            return None
        return self.done_s - self.submitted_s

    @property
    def met_deadline(self) -> Optional[bool]:
        """Whether completion beat the deadline (None = no deadline)."""
        if self.deadline_s is None or self.done_s is None:
            return None
        return self.done_s <= self.deadline_s


@dataclasses.dataclass
class _Request:
    """A queued request: the ticket plus its host-side operand."""

    ticket: Ticket
    b: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One executed micro-batch's audit row (``ServingEngine.batch_log``).

    The serving loop's per-batch decisions stay inspectable the way
    ``DispatchPlan.summary()`` keeps dispatch decisions inspectable:
    which operator, which requests, how wide, how long.
    """

    seq: int
    operator: str
    chosen: str                   # format the plan executed
    request_ids: Tuple[int, ...]
    widths: Tuple[int, ...]       # per-request d
    cols: int                     # total columns incl. padding
    block_d: int                  # per-launch width the batch replayed at
    queued_s: float               # oldest member's admission->draft wait
    exec_s: float                 # draft -> materialized


@dataclasses.dataclass
class _Staged:
    """A drafted batch staged on device, awaiting dispatch."""

    plan: StreamPlan
    requests: List[_Request]
    b_dev: jnp.ndarray
    block_d: int
    cols: int


class ServingEngine:
    """Request-queue serving loop over registered persistent plans.

    Deterministic core + optional worker thread: :meth:`submit` /
    :meth:`step` / :meth:`drain` are a single-threaded API (tests drive
    it with an injected fake clock); :meth:`start` runs the same loop on
    a daemon thread so ``submit`` becomes fire-and-forget from any
    thread.

    Args:
        max_queue: bounded-queue depth; admission beyond it applies the
            backpressure policy.
        policy: ``"wait"`` (block the submitter until space) or
            ``"shed"`` (raise :class:`ShedError` immediately).
        max_batch_cols: column budget per micro-batch; None derives it
            per plan from the staging budget (:func:`coalesce_budget`).
        stage_bytes: staging-buffer budget behind the derived column
            budget.
        clock: monotonic-seconds callable; injectable for deterministic
            latency tests (default ``time.monotonic``).
        double_buffer: stage the next batch between dispatching and
            blocking on the current one (disabled automatically when the
            plan's kernel reports ``async_dispatch=False`` — without
            async dispatch there is no compute to overlap with).
        auto_replan: poll ``plan.maybe_replan()`` after each batch and
            swap the fresh plan in atomically when the reuse audit fires.
        batch_log_depth: how many :class:`BatchRecord` rows to retain.
    """

    def __init__(self, *, max_queue: int = DEFAULT_MAX_QUEUE,
                 policy: str = "wait",
                 max_batch_cols: Optional[int] = None,
                 stage_bytes: int = DEFAULT_STAGE_BYTES,
                 clock: Callable[[], float] = time.monotonic,
                 double_buffer: bool = True,
                 auto_replan: bool = True,
                 batch_log_depth: int = 64):
        if policy not in ("wait", "shed"):
            raise ValueError(
                f"policy must be 'wait' or 'shed', got {policy!r}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self._plans: Dict[str, StreamPlan] = {}
        self._queue: Deque[_Request] = collections.deque()
        self._lock = threading.RLock()
        self._space = threading.Condition(self._lock)   # waiters on a full q
        self._work = threading.Condition(self._lock)    # worker wake-up
        self.max_queue = max_queue
        self.policy = policy
        self.max_batch_cols = max_batch_cols
        self.stage_bytes = stage_bytes
        self.clock = clock
        self.double_buffer = double_buffer
        self.auto_replan = auto_replan
        self.batch_log: Deque[BatchRecord] = collections.deque(
            maxlen=batch_log_depth)
        self._staged: Optional[_Staged] = None
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._seq = 0
        self._batch_seq = 0
        self._latencies: List[float] = []
        self._counts = {"admitted": 0, "served": 0, "shed": 0,
                        "batches": 0, "coalesced": 0, "replans": 0,
                        "deadline_miss": 0}
        self._first_submit_s: Optional[float] = None
        self._last_done_s: Optional[float] = None

    # ------------------------------------------------------------- #
    # Operators
    # ------------------------------------------------------------- #

    def register(self, name: str, plan: StreamPlan) -> StreamPlan:
        """Register ``plan`` as operator ``name``; returns the plan.

        A sharded plan (``sparse.plan(m, spec, mesh=...)``) registers the
        same way — the engine consults its ``exec_hints`` /
        ``coalesce_block_d`` overrides and otherwise treats it as any
        other plan.
        """
        with self._lock:
            self._plans[name] = plan
        return plan

    def plan_for(self, name: str) -> StreamPlan:
        """The plan currently serving operator ``name`` (post any swaps)."""
        with self._lock:
            return self._plans[name]

    def budget_for(self, name: str) -> int:
        """The micro-batch column budget applied to operator ``name``."""
        plan = self.plan_for(name)
        if self.max_batch_cols is not None:
            return max(self.max_batch_cols, plan.spec.d)
        return coalesce_budget(plan, stage_bytes=self.stage_bytes)

    def warmup(self, name: str, *, max_cols: Optional[int] = None) -> int:
        """Prime the compiled-launch cache for operator ``name``.

        Coalesced batches replay at quantized widths
        (``plan.coalesce_block_d``), and each distinct width jit-compiles
        once; serving traffic through cold size classes puts those
        compiles inside request latencies.  This runs one zero-operand
        ``execute_wide`` per size class up to the column budget (or
        ``max_cols``), then resets the plan's execution counter so the
        warm-up doesn't skew its reuse audit.

        Args:
            name: a registered operator.
            max_cols: cap on the largest class to warm; defaults to the
                operator's coalescing budget.

        Returns:
            Number of distinct launch widths warmed.
        """
        plan = self.plan_for(name)
        cap = self.budget_for(name) if max_cols is None else max(
            int(max_cols), plan.spec.d)
        classes = []
        cols = plan.spec.d
        while True:
            block = plan.coalesce_block_d(cols)
            if block not in classes:
                classes.append(block)
            if cols >= cap:
                break
            cols = min(cols * 2, cap)
        for block in classes:
            b = jnp.zeros((plan.n, block), _stage_dtype(plan))
            jax.block_until_ready(plan.execute_wide(b, block_d=block))
        plan.reset_stats()
        return len(classes)

    def reset_stats(self) -> None:
        """Zero latency/counter accounting (e.g. after a warm-up wave).

        Registered plans, queue contents, and ticket-id numbering are
        untouched; only the served-request accounting (latencies,
        counters, batch log, goodput span) restarts.
        """
        with self._lock:
            self._latencies.clear()
            self.batch_log.clear()
            for k in self._counts:
                self._counts[k] = 0
            self._first_submit_s = None
            self._last_done_s = None

    # ------------------------------------------------------------- #
    # Admission (stage 1)
    # ------------------------------------------------------------- #

    def submit(self, operator: str, b: jnp.ndarray, *,
               deadline_s: Optional[float] = None,
               timeout: Optional[float] = None) -> Ticket:
        """Admit one request; returns its :class:`Ticket`.

        Args:
            operator: a name previously :meth:`register`-ed.
            b: dense right-hand side ``[n, d]`` (any width; requests of
                mixed widths coalesce into shared batches).
            deadline_s: optional deadline in seconds *from admission*;
                missed deadlines are counted (and excluded from goodput)
                but the request is still served.
            timeout: under ``policy="wait"``, how long to block for queue
                space before shedding anyway; None waits forever.

        Raises:
            KeyError: unknown operator.
            ValueError: operand shape incompatible with the plan.
            ShedError: queue full under ``"shed"``, or wait timed out.
        """
        t0 = self.clock()
        with self._lock:
            plan = self._plans[operator]        # KeyError = unknown operator
        if getattr(b, "ndim", 0) != 2 or b.shape[0] != plan.n:
            raise ValueError(
                f"operand shape {tuple(getattr(b, 'shape', ()))} "
                f"incompatible with operator {operator!r} for "
                f"[{plan.n}, {plan.n}] matrix; expected [{plan.n}, d]")
        ticket = Ticket(
            id=-1, operator=operator, d=int(b.shape[1]),
            deadline_s=None if deadline_s is None else t0 + deadline_s,
            submitted_s=t0)
        with self._space:
            while len(self._queue) >= self.max_queue:
                if self.policy == "shed":
                    self._counts["shed"] += 1
                    raise ShedError(
                        f"queue full ({self.max_queue}); request for "
                        f"{operator!r} shed at admission")
                if not self._space.wait(timeout):
                    self._counts["shed"] += 1
                    raise ShedError(
                        f"queue full ({self.max_queue}) for {timeout}s; "
                        f"request for {operator!r} shed after waiting")
            ticket.id = self._seq
            self._seq += 1
            self._counts["admitted"] += 1
            if self._first_submit_s is None:
                self._first_submit_s = t0
            self._queue.append(_Request(ticket=ticket, b=b))
            self._work.notify_all()
        return ticket

    def pending(self) -> int:
        """Requests admitted but not yet drafted into a batch."""
        with self._lock:
            return len(self._queue)

    # ------------------------------------------------------------- #
    # Coalescing + staging (stages 2-3)
    # ------------------------------------------------------------- #

    def _draft(self) -> Optional[Tuple[StreamPlan, List[_Request]]]:
        """Pop the next micro-batch from the queue (stage 2, under lock).

        The queue head anchors the batch; every other queued request for
        the same operator joins in FIFO order until the column budget is
        hit.  Requests for other operators keep their relative order and
        wait for a later batch — the head is always served, so no
        operator starves.
        """
        with self._lock:
            if not self._queue:
                return None
            head = self._queue.popleft()
            op = head.ticket.operator
            plan = self._plans[op]
            budget = (max(self.max_batch_cols, plan.spec.d)
                      if self.max_batch_cols is not None
                      else coalesce_budget(plan,
                                           stage_bytes=self.stage_bytes))
            batch = [head]
            cols = head.ticket.d
            rest: List[_Request] = []
            while self._queue:
                req = self._queue.popleft()
                if (req.ticket.operator == op
                        and cols + req.ticket.d <= budget):
                    batch.append(req)
                    cols += req.ticket.d
                else:
                    rest.append(req)
            self._queue.extend(rest)
            self._space.notify_all()
            return plan, batch

    def _stage(self) -> Optional[_Staged]:
        """Draft the next batch and move its operand to device (stage 3).

        Host-side work only — column concatenation, padding to a multiple
        of the plan's ``coalesce_block_d``, and an asynchronous
        ``device_put`` — so calling this between dispatching and blocking
        on the previous batch overlaps the transfer with device compute.
        """
        drafted = self._draft()
        if drafted is None:
            return None
        plan, batch = drafted
        t_batch = self.clock()
        for req in batch:
            req.ticket.batched_s = t_batch
        cols = sum(r.ticket.d for r in batch)
        block_d = plan.coalesce_block_d(cols)
        pad = (-cols) % block_d
        # Concatenate on the host (NumPy), not with jnp: an eager
        # jnp.concatenate compiles one XLA program per distinct
        # width-combination, and arrival timing makes nearly every batch
        # a new combination — recompiles would dominate the batch.  One
        # memcpy-shaped concat plus a single device_put is the staging
        # transfer the double buffering exists to overlap.  Staging casts
        # to the plan's precision dtype here, on the host, so a bf16 plan
        # moves half the bytes per batch.
        stage_dt = np.dtype(_stage_dtype(plan))
        parts = [np.asarray(r.b, dtype=stage_dt) for r in batch]
        if pad:
            parts.append(np.zeros((plan.n, pad), stage_dt))
        wide = parts[0] if len(parts) == 1 else np.concatenate(
            parts, axis=1)
        return _Staged(plan=plan, requests=batch,
                       b_dev=jax.device_put(wide), block_d=block_d,
                       cols=cols + pad)

    # ------------------------------------------------------------- #
    # Execution (stage 4)
    # ------------------------------------------------------------- #

    def step(self) -> int:
        """Execute one micro-batch; returns the number of requests served.

        Consumes the staged batch if double buffering left one, else
        drafts fresh; dispatches its single ``execute_wide`` call; stages
        the *next* batch while the device computes (when the plan's
        kernel dispatches asynchronously — ``exec_hints``); blocks once;
        then slices per-request results out and completes the tickets.
        Returns 0 when the queue is idle.
        """
        staged = self._staged
        self._staged = None
        if staged is None:
            staged = self._stage()
        if staged is None:
            return 0
        plan, batch = staged.plan, staged.requests
        hints = plan.exec_hints()
        try:
            out = plan.execute_wide(staged.b_dev, block_d=staged.block_d)
            if hints.get("donate_b"):
                # The launch consumed the staged buffer; drop our alias
                # now rather than at materialization.
                staged.b_dev = None
            if self.double_buffer and hints.get("async_dispatch", True):
                self._staged = self._stage()    # overlaps device compute
            jax.block_until_ready(out)
        except Exception as exc:               # noqa: BLE001 - delivered
            t_done = self.clock()
            for req in batch:
                req.ticket._error = exc
                req.ticket.done_s = t_done
                req.ticket._event.set()
            raise
        # Slice per-request results from the materialized host array:
        # eager jnp slices compile per (offset, width) pair, so a mixed
        # batch would pay a compile per ticket; NumPy views are free and
        # the batch is already synced.
        host = np.asarray(out)
        t_done = self.clock()
        lo = 0
        for req in batch:
            tk = req.ticket
            tk._result = host[:, lo:lo + tk.d]
            lo += tk.d
            tk.done_s = t_done
            tk.batch_seq = self._batch_seq
            tk._event.set()
        with self._lock:
            self._batch_seq += 1
            self._counts["batches"] += 1
            self._counts["served"] += len(batch)
            if len(batch) > 1:
                self._counts["coalesced"] += len(batch)
            self._counts["deadline_miss"] += sum(
                1 for r in batch if r.ticket.met_deadline is False)
            self._latencies.extend(r.ticket.latency_s for r in batch)
            self._last_done_s = t_done
            oldest = min(r.ticket.submitted_s for r in batch)
            self.batch_log.append(BatchRecord(
                seq=self._batch_seq - 1, operator=batch[0].ticket.operator,
                chosen=plan.chosen,
                request_ids=tuple(r.ticket.id for r in batch),
                widths=tuple(r.ticket.d for r in batch),
                cols=staged.cols, block_d=staged.block_d,
                queued_s=batch[0].ticket.batched_s - oldest,
                exec_s=t_done - batch[0].ticket.batched_s))
        if self.auto_replan:
            self._maybe_swap(batch[0].ticket.operator)
        return len(batch)

    def _maybe_swap(self, operator: str) -> None:
        """Atomic mid-stream plan swap when the reuse audit fired.

        ``maybe_replan`` rebuilds (and fully binds) the plan *outside*
        the serving lock; only the reference swap happens under it, so
        admission never stalls behind a re-plan.  Batches already staged
        against the old plan run to completion on it.
        """
        with self._lock:
            plan = self._plans.get(operator)
        if plan is None:
            return
        fresh = plan.maybe_replan()
        if fresh is None:
            return
        with self._lock:
            # Swap only if nobody else swapped meanwhile.
            if self._plans.get(operator) is plan:
                self._plans[operator] = fresh
                self._counts["replans"] += 1

    def drain(self) -> int:
        """Serve until the queue (and any staged batch) is empty.

        Returns:
            Total requests served by this call.
        """
        total = 0
        while True:
            served = self.step()
            if served == 0 and self._staged is None:
                with self._lock:
                    if not self._queue:
                        return total
            total += served

    # ------------------------------------------------------------- #
    # Worker thread
    # ------------------------------------------------------------- #

    def start(self) -> None:
        """Spawn the worker thread consuming the queue (idempotent)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return
            self._stopping = False
            self._thread = threading.Thread(
                target=self._worker, name="serving-engine", daemon=True)
            self._thread.start()

    def stop(self, *, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Stop the worker thread.

        Args:
            drain: serve everything already admitted before exiting;
                False abandons queued requests (their tickets never
                complete — callers using ``result(timeout=...)`` see a
                ``TimeoutError``).
            timeout: join timeout in seconds.
        """
        with self._lock:
            self._stopping = True
            self._drain_on_stop = drain
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def _worker(self) -> None:
        """Worker loop: wait for admissions, serve batches until stopped.

        The wake condition covers the staged batch too: double buffering
        can leave a drafted batch in ``self._staged`` after the queue
        empties, and waiting on admissions alone would strand it (and
        its requests) until the next submit.
        """
        while True:
            with self._work:
                while (not self._queue and self._staged is None
                       and not self._stopping):
                    self._work.wait(0.1)
                if self._stopping and (
                        not getattr(self, "_drain_on_stop", True)
                        or not self._queue):
                    if self._staged is None:
                        return
            self.step()

    # ------------------------------------------------------------- #
    # Accounting
    # ------------------------------------------------------------- #

    def stats(self) -> dict:
        """Counters + latency percentiles + goodput, as one dict.

        Keys: ``admitted`` / ``served`` / ``shed`` / ``batches`` /
        ``coalesced`` (requests that shared a batch) / ``replans`` /
        ``deadline_miss`` / ``queue_depth`` / ``mean_batch_cols`` /
        ``p50_us`` / ``p99_us`` (percentiles over served requests'
        submit-to-completion latencies) / ``goodput_rps`` (deadline-
        meeting completions per second of serving wall time) /
        ``operators`` (each registered plan's own ``stats()``).
        """
        with self._lock:
            lats = list(self._latencies)
            counts = dict(self._counts)
            depth = len(self._queue)
            log = list(self.batch_log)
            span = ((self._last_done_s - self._first_submit_s)
                    if self._latencies and self._first_submit_s is not None
                    else 0.0)
            ops = {name: p.stats() for name, p in self._plans.items()}
        good = counts["served"] - counts["deadline_miss"]
        out = dict(counts)
        out.update({
            "queue_depth": depth,
            "mean_batch_cols": (float(np.mean([r.cols for r in log]))
                                if log else 0.0),
            "p50_us": float(np.percentile(lats, 50) * 1e6) if lats else 0.0,
            "p99_us": float(np.percentile(lats, 99) * 1e6) if lats else 0.0,
            "goodput_rps": good / span if span > 0 else 0.0,
            "operators": ops,
        })
        return out

    def summary(self) -> str:
        """Human-readable audit: counters plus the recent batch log."""
        s = self.stats()
        lines = [
            f"ServingEngine(policy={self.policy}, "
            f"max_queue={self.max_queue}): "
            f"admitted={s['admitted']} served={s['served']} "
            f"shed={s['shed']} batches={s['batches']} "
            f"coalesced={s['coalesced']} replans={s['replans']}",
            f"  latency p50={s['p50_us']:.0f}us p99={s['p99_us']:.0f}us  "
            f"goodput={s['goodput_rps']:.1f} req/s  "
            f"deadline_miss={s['deadline_miss']}",
        ]
        for rec in list(self.batch_log)[-8:]:
            lines.append(
                f"  batch {rec.seq:4d} {rec.operator:>12s}[{rec.chosen}] "
                f"x{len(rec.request_ids)} widths={list(rec.widths)} "
                f"cols={rec.cols} block_d={rec.block_d} "
                f"queued={rec.queued_s * 1e6:.0f}us "
                f"exec={rec.exec_s * 1e6:.0f}us")
        return "\n".join(lines)
