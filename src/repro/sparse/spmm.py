"""SpMM implementations per sparse layout (pure JAX, jit-compiled).

These are the *system under test* for the paper's benchmarks on this host,
and the reference semantics for the Pallas TPU kernels in repro.kernels.

  csr_spmm   gather rows of B per nonzero, multiply, segment-sum by row
             (the paper's CSR implementation; worst-case traffic).
  ell_spmm   padded, fully vectorized column-slot loop (vendor-style).
  bcsr_spmm  batched dense t x t block matmuls + block-row segment sum
             (the paper's CSB, restructured for matrix units).
  dia_spmm   per-diagonal shifted axpy (the diagonal regime realized).

Scale-free-regime variants (PR 8) share the gather/segment-sum algebra
but traverse different host-prepared orders:

  binned_spmm    slab-major traversal (two-phase propagation blocking).
  rowsplit_spmm  equal-nnz chunk traversal (merge-path load balance).
  ell_coo_spmm   vectorized ELL body + COO-tail gather/segment-sum.

All return C = A @ B with C: [n, d] in the operand dtype.  Reduced
precisions (bf16 containers + bf16 B) round only the *products*:
every accumulation runs in fp32 (explicit upcast before the segment
sum / scan carry, ``preferred_element_type`` on the matmuls) and the
result is cast back once at the end — the same contract as the Pallas
kernels' fp32 VMEM accumulators.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse.formats import (
    BCSRMatrix, BinnedMatrix, CSRMatrix, DIAMatrix, ELLCOOMatrix, ELLMatrix,
    RowSplitMatrix)


@jax.jit
def csr_spmm(a: CSRMatrix, b: jnp.ndarray) -> jnp.ndarray:
    """C[r] += val * B[c] for every nonzero (r, c, val)."""
    gathered = b[a.indices]                       # [nnz, d] random gather
    scaled = gathered * a.data[:, None]           # [nnz, d]
    out = jax.ops.segment_sum(scaled.astype(jnp.float32), a.row_ids,
                              num_segments=a.n)
    return out.astype(b.dtype)


@jax.jit
def ell_spmm(a: ELLMatrix, b: jnp.ndarray) -> jnp.ndarray:
    """Vectorized over the padded slot dimension; zero padding is harmless."""

    def _slot(carry, k):
        acc = carry
        cols = a.indices[:, k]                    # [n]
        vals = a.data[:, k]                       # [n]
        acc = acc + (b[cols] * vals[:, None]).astype(jnp.float32)
        return acc, None

    init = jnp.zeros((a.n, b.shape[1]), dtype=jnp.float32)
    out, _ = jax.lax.scan(_slot, init, jnp.arange(a.k))
    return out.astype(b.dtype)


@jax.jit
def bcsr_spmm(a: BCSRMatrix, b: jnp.ndarray) -> jnp.ndarray:
    """Batched block matmul: the XLA-native form of the CSB traversal.

    B is viewed as nb tiles of shape [t, d]; each nonzero block multiplies
    its column tile and accumulates into its row tile.
    """
    d = b.shape[1]
    b_tiles = b.reshape(a.nb, a.t, d)
    gathered = b_tiles[a.block_cols]              # [N, t, d]
    prods = jnp.einsum("nij,njd->nid", a.blocks, gathered,
                       preferred_element_type=jnp.float32)
    out_tiles = jax.ops.segment_sum(prods, a.block_rows, num_segments=a.nb)
    return out_tiles.reshape(a.n, d).astype(b.dtype)


@jax.jit
def dia_spmm(a: DIAMatrix, b: jnp.ndarray) -> jnp.ndarray:
    """C[r] += diag_k[r] * B[r + off_k]; offsets are static so this unrolls
    into num_offsets shifted multiplies — exactly one streaming pass over B
    per diagonal (the paper's 'B loaded once' regime when offsets are few).

    The shift is a static slice + zero pad rather than an index gather, so
    XLA emits pure streaming copies (no gather unit / scatter traffic) and
    the kernel runs at axpy speed — the behavior Eq. 3 charges for.
    """
    n, d = a.n, b.shape[1]
    out = None
    for i, off in enumerate(a.offsets):
        if off >= 0:
            # rows [0, n-off) read b[off:]; rows past n-off fall off the band.
            shifted = jnp.concatenate(
                [b[off:], jnp.zeros((off, d), b.dtype)]) if off else b
        else:
            shifted = jnp.concatenate(
                [jnp.zeros((-off, d), b.dtype), b[:n + off]])
        contrib = (a.data[i][:, None] * shifted).astype(jnp.float32)
        out = contrib if out is None else out + contrib
    if out is None:
        out = jnp.zeros((n, d), dtype=jnp.float32)
    return out.astype(b.dtype)


@partial(jax.jit, static_argnames=("block_rows_per_step",))
def bcsr_spmm_scan(a: BCSRMatrix, b: jnp.ndarray,
                   block_rows_per_step: int = 1) -> jnp.ndarray:
    """Memory-lean BCSR SpMM: scan over nonzero blocks without materializing
    the [N, t, d] product tensor.  Mirrors the Pallas kernel's grid walk and
    is used as its CPU wall-clock proxy for large N.
    """
    d = b.shape[1]
    b_tiles = b.reshape(a.nb, a.t, d)

    def _step(acc, blk):
        block, br, bc = blk
        prod = jnp.dot(block, b_tiles[bc],
                       preferred_element_type=jnp.float32)
        acc = acc.at[br].add(prod)
        return acc, None

    init = jnp.zeros((a.nb, a.t, d), dtype=jnp.float32)
    out, _ = jax.lax.scan(_step, init,
                          (a.blocks, a.block_rows, a.block_cols))
    return out.reshape(a.n, d).astype(b.dtype)


@jax.jit
def binned_spmm(a: BinnedMatrix, b: jnp.ndarray) -> jnp.ndarray:
    """Slab-major gather/segment-sum: same algebra as ``csr_spmm``, but the
    nonzero stream arrives grouped by B-row slab (ascending columns inside
    each slab), so consecutive gathers hit one cache/VMEM-resident slab of
    B — the traversal the binned AI model charges for.
    """
    gathered = b[a.cols]                          # [nnz, d] slab-local reuse
    scaled = gathered * a.data[:, None]           # [nnz, d]
    out = jax.ops.segment_sum(scaled.astype(jnp.float32), a.rows,
                              num_segments=a.n)
    return out.astype(b.dtype)


@jax.jit
def rowsplit_spmm(a: RowSplitMatrix, b: jnp.ndarray) -> jnp.ndarray:
    """Equal-nnz chunk traversal: padding entries carry value 0 at row 0,
    so the segment sum absorbs them without masking."""
    if a.data.shape[0] == 0:
        return jnp.zeros((a.n, b.shape[1]), dtype=b.dtype)
    gathered = b[a.cols]                          # [P, d]
    scaled = gathered * a.data[:, None]           # [P, d]
    out = jax.ops.segment_sum(scaled.astype(jnp.float32), a.rows,
                              num_segments=a.n)
    return out.astype(b.dtype)


@jax.jit
def ell_coo_spmm(a: ELLCOOMatrix, b: jnp.ndarray) -> jnp.ndarray:
    """Vectorized body (the ELL slot loop up to ``k_cut``) plus a COO-tail
    gather/segment-sum for the overflow entries of hub rows."""

    def _slot(carry, k):
        acc = carry
        cols = a.body_indices[:, k]               # [n]
        vals = a.body_data[:, k]                  # [n]
        acc = acc + (b[cols] * vals[:, None]).astype(jnp.float32)
        return acc, None

    init = jnp.zeros((a.n, b.shape[1]), dtype=jnp.float32)
    out, _ = jax.lax.scan(_slot, init, jnp.arange(a.k_cut))
    if a.tail_data.shape[0]:
        tail = b[a.tail_cols] * a.tail_data[:, None]     # [tail_nnz, d]
        out = out + jax.ops.segment_sum(tail.astype(jnp.float32),
                                        a.tail_rows, num_segments=a.n)
    return out.astype(b.dtype)


def dense_spmm(a_dense: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Dense reference (XLA matmul) — the 'vendor peak' comparison point."""
    return a_dense @ b


IMPLEMENTATIONS = {
    "csr": csr_spmm,
    "ell": ell_spmm,
    "bcsr": bcsr_spmm,
    "dia": dia_spmm,
    "binned": binned_spmm,
    "rowsplit": rowsplit_spmm,
    "ell_coo": ell_coo_spmm,
}
