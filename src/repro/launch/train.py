"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 200 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` runs the smoke-scale config on the host; the full config +
production mesh path is exercised via launch.dryrun (this container has one
CPU device).  On a real TPU slice the same command with --mesh data,model
spawns the pjit'd trainer.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs.base import SHAPES, ShapeConfig, get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    """Train an arch/shape cell from the CLI (see module docstring)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--mesh", default="",
                    help="e.g. '4,2' => (data=4, model=2) over host devices")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = SHAPES[args.shape]
    if args.seq_len or args.batch:
        shape = ShapeConfig("custom", args.seq_len or shape.seq_len,
                            args.batch or shape.global_batch, "train")
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_host_mesh(d, m)

    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         grad_accum=args.grad_accum,
                         schedule_kwargs={"warmup_steps": args.warmup,
                                          "total_steps": args.steps})
    trainer = Trainer(cfg, shape, tcfg, mesh=mesh,
                      opt_cfg=adamw.AdamWConfig(lr=args.lr),
                      data_cfg=DataConfig(seed=0))
    start = trainer.init_or_restore()
    print(f"devices={jax.device_count()} params="
          f"{cfg.param_count() / 1e6:.1f}M start_step={start}")
    metrics = trainer.run(args.steps)
    print("final metrics:", metrics)
    if trainer.straggler_events:
        print(f"stragglers observed: {len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
