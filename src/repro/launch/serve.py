"""Serving launcher: batched LM decode, plus the streamed-SpMM serving path.

LM serving (prefill + greedy decode with a KV cache):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Streamed SpMM serving (``--spmm-stream``): hold one sparse operator for
the whole process, plan once through ``sparse.plan`` with the expected
request count as the reuse horizon, and serve every per-step right-hand
side through the bound kernel (``docs/serving.md``):

    PYTHONPATH=src python -m repro.launch.serve --spmm-stream \
        --spmm-structure moe-block --spmm-n 4096 --spmm-d 64 \
        --spmm-steps 64

``--spmm-shards N`` serves the same stream through the sharded tier
(``repro.sparse.shard``): the plan partitions the operator across an
N-device mesh and replays under ``shard_map``; the printed summary adds
the B-distribution strategy audit (``docs/sharding.md``):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --spmm-stream \
        --spmm-shards -1 --spmm-structure moe-block

``--engine`` serves the same operator through the continuous-batching
engine (``repro.sparse.engine``): a synthetic open-loop arrival process
plays ``--engine-streams`` concurrent request streams with mixed
d-widths into the bounded queue, the worker thread coalesces compatible
requests into shared ``execute_wide`` calls, and the report adds
per-request p50/p99 latency and goodput next to an engine-vs-sync
comparison (``docs/serving_engine.md``):

    PYTHONPATH=src python -m repro.launch.serve --engine \
        --spmm-structure moe-block --spmm-n 4096 --spmm-d 64 \
        --engine-streams 4 --engine-requests 64 --engine-rate 2000

``--calibrate`` runs the on-host compute-ceiling calibration
(``repro.core.calibrate``) at startup and persists it, so the serving
plan predicts from measured ``(peak_fraction, d_half)`` ceilings.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.patterns import serving_suite


def generate(cfg, params, prompts: np.ndarray, gen: int):
    """Greedy decode ``gen`` tokens after prefilling ``prompts`` [B,S]."""
    from repro.models import model as M
    B, S = prompts.shape
    cache = M.init_cache(cfg, B, S + gen)
    # Prefill by stepping (teacher forcing) — a production server would
    # batch-prefill; the dry-run prefill cells cover that path.
    tok = jnp.asarray(prompts[:, 0])
    step = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    for t in range(S - 1):
        _, cache = step(params, cache, jnp.asarray(prompts[:, t]),
                        jnp.int32(t))
    tok = jnp.asarray(prompts[:, -1])
    out = []
    for t in range(gen):
        logits, cache = step(params, cache, tok, jnp.int32(S - 1 + t))
        tok = jnp.argmax(
            logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.stack(out, axis=1)


#: CLI choices derive from the shared registry so they can't drift from it.
STREAM_STRUCTURES = tuple(serving_suite(64))


def build_stream_matrix(structure: str, n: int):
    """Build the served sparse operator for one of the paper structures.

    ``moe-block`` is the serving-path case the repo targets: the MoE
    expert-dispatch matrix — dense t x t blocks on the diagonal, one per
    expert token bucket (repro.models.moe routes tokens into exactly this
    shape; see examples/moe_block_sparse.py).  The rest are the paper's
    Table III regimes at serving scale.  All four come from the shared
    registry ``repro.core.patterns.serving_suite``, which
    ``benchmarks/stream.py`` measures.
    """
    suite = serving_suite(n)
    if structure not in suite:
        raise ValueError(f"unknown structure {structure!r}; choose from "
                         f"{STREAM_STRUCTURES}")
    return suite[structure]()


def run_startup_calibration() -> None:
    """Calibrate the per-format compute ceilings for the serving host.

    Runs the short ``repro.core.calibrate`` sweep against the hardware
    spec the default dispatcher resolves to, persists the result to the
    default :class:`~repro.core.calibrate.CalibrationStore`, and
    refreshes the dispatcher so every subsequent plan (including the
    ``--spmm-stream`` serving plan) predicts from measured ceilings
    (``ceiling_source="calibrated"``) instead of the baked-in defaults.
    """
    from repro import sparse
    from repro.core.calibrate import CalibrationStore, calibrate

    disp = sparse.default_dispatcher()
    backend = disp._resolve_backend()
    hw = disp._resolve_hardware(backend)
    t0 = time.perf_counter()
    store = CalibrationStore()
    cal = calibrate(hw, backend=backend, store=store)
    disp.refresh_calibration()
    print(f"startup calibration ({backend} kernels on {hw.name}) took "
          f"{time.perf_counter() - t0:.1f}s -> {store.path_for(hw, backend)}")
    print(cal.summary())


def serve_spmm_stream(args) -> None:
    """Serve ``--spmm-steps`` right-hand sides through one persistent plan."""
    from repro import sparse
    m = build_stream_matrix(args.spmm_structure, args.spmm_n)
    rng = np.random.default_rng(1)

    def next_batch():
        return jnp.asarray(
            rng.normal(size=(m.n, args.spmm_d)).astype(np.float32))

    mesh = None
    shards = getattr(args, "spmm_shards", 0)    # absent on hand-built args
    if shards:
        from repro.launch.mesh import make_shard_mesh
        mesh = make_shard_mesh(None if shards < 0 else shards)

    t0 = time.perf_counter()
    plan = sparse.plan(m, sparse.BSpec(d=args.spmm_d, reuse=args.spmm_steps),
                       mesh=mesh)
    jax.block_until_ready(plan.execute(next_batch()))   # bind + compile
    startup_s = time.perf_counter() - t0
    plan.reset_stats()     # the warm-up is startup, not a served request

    lat = []
    for _ in range(args.spmm_steps):
        b = next_batch()
        t1 = time.perf_counter()
        jax.block_until_ready(plan.execute(b))
        lat.append(time.perf_counter() - t1)
    lat_us = np.asarray(lat) * 1e6
    flops = 2.0 * m.nnz * args.spmm_d

    # ShardedPlan.summary() adds the B-strategy audit under the format
    # decision table; the single-device plan prints the table alone.
    print(plan.summary() if mesh is not None else plan.dispatch.summary())
    single = sparse.plan_spmm(m, args.spmm_d, reuse=1)
    note = ("same as single-shot" if single.chosen == plan.chosen else
            f"single-shot would pick {single.chosen}")
    print(f"serving {args.spmm_structure} [{m.n}x{m.n}, nnz={m.nnz}] "
          f"d={args.spmm_d}: planned for reuse={args.spmm_steps} "
          f"-> {plan.chosen} ({note})")
    print(f"startup (classify+plan+convert+compile): {startup_s * 1e3:.1f} ms")
    print(f"steady-state: p50={np.percentile(lat_us, 50):.0f}us "
          f"p99={np.percentile(lat_us, 99):.0f}us "
          f"-> {flops / np.median(lat_us) / 1e3:.2f} GFLOP/s")

    if args.spmm_compare:
        # Replay the exact same stream: reseed so the draws repeat the
        # streamed run (one warm-up batch, then the served batches).
        rng = np.random.default_rng(1)
        # Warm the single-shot format's kernel first: it can differ from
        # the streamed choice, and its one-time jit compile would
        # otherwise land inside the first timed iteration.
        jax.block_until_ready(
            sparse.Dispatcher(backend=plan.dispatch.backend)
            .spmm(m, next_batch(), reuse=1))
        # Time only the dispatch+execute, like the streamed loop above —
        # host-side RHS generation is excluded from both sides.
        percall_s = 0.0
        for _ in range(args.spmm_steps):
            b = next_batch()
            t2 = time.perf_counter()
            jax.block_until_ready(
                sparse.Dispatcher(backend=plan.dispatch.backend)
                .spmm(m, b, reuse=1))
            percall_s += time.perf_counter() - t2
        streamed_s = float(np.sum(lat))
        print(f"per-call dispatch (fresh dispatcher per request, no "
              f"caches) of the same stream: {percall_s * 1e3:.1f} ms vs "
              f"streamed {streamed_s * 1e3:.1f} ms "
              f"({percall_s / max(streamed_s, 1e-12):.1f}x; "
              f"a warm-cache per-call baseline sits between — see "
              f"benchmarks/stream.py percall_cached)")
    print(f"stats: {plan.stats()}")


def serve_spmm_engine(args) -> None:
    """Serve an open-loop arrival process through the serving engine.

    ``--engine-streams`` synthetic clients each submit
    ``--engine-requests`` right-hand sides with exponential
    inter-arrival gaps (open loop: arrivals don't wait for completions,
    so the queue actually exercises coalescing and backpressure).
    Stream widths alternate ``d`` and ``d // 2`` to show mixed-width
    coalescing.  After the engine drains, the same request sequence is
    replayed through synchronous per-request ``plan.execute`` calls and
    both sides report p50/p99 per-request latency and goodput
    (``docs/serving_engine.md`` walks through one of these transcripts).
    """
    import threading

    from repro import sparse

    m = build_stream_matrix(args.spmm_structure, args.spmm_n)
    streams = max(args.engine_streams, 1)
    per_stream = max(args.engine_requests // streams, 1)
    rate = max(args.engine_rate, 1e-9)      # requests/s per stream

    def width(stream: int) -> int:
        return args.spmm_d if stream % 2 == 0 else max(args.spmm_d // 2, 1)

    # Pre-draw every operand so generation cost stays out of both timings.
    rng = np.random.default_rng(1)
    reqs = [[jnp.asarray(rng.normal(size=(m.n, width(s)))
                         .astype(np.float32)) for _ in range(per_stream)]
            for s in range(streams)]
    gaps = [[rng.exponential(1.0 / rate) for _ in range(per_stream)]
            for _ in range(streams)]
    total = streams * per_stream

    t0 = time.perf_counter()
    plan = sparse.plan(m, sparse.BSpec(d=args.spmm_d, reuse=total))
    jax.block_until_ready(plan.execute(reqs[0][0]))   # bind + compile
    plan.reset_stats()

    engine = sparse.ServingEngine(
        max_queue=args.engine_queue, policy=args.engine_policy)
    engine.register("spmm", plan)
    # Prime every coalesced launch width the run can reach, so jit
    # compiles land in startup instead of inside request latencies.
    worst_case_cols = sum(b.shape[1] for stream in reqs for b in stream)
    warmed = engine.warmup("spmm", max_cols=worst_case_cols)
    startup_s = time.perf_counter() - t0
    engine.start()

    def client(stream: int, tickets: list) -> None:
        for gap, b in zip(gaps[stream], reqs[stream]):
            time.sleep(gap)
            try:
                tickets.append(engine.submit("spmm", b))
            except sparse.ShedError:
                pass                        # counted in engine.stats()

    tickets: list = []
    per_client: list = [[] for _ in range(streams)]
    threads = [threading.Thread(target=client, args=(s, per_client[s]))
               for s in range(streams)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for lst in per_client:
        tickets.extend(lst)
    for t in tickets:
        t.result(timeout=120.0)
    engine.stop()
    stats = engine.stats()

    # Sync baseline: per-request replay of the identical sequence on the
    # same plan, one block_until_ready per request.  Warm each distinct
    # request width first — the engine got its launch widths warmed at
    # startup, so the baseline gets the same courtesy.
    for w in sorted({b.shape[1] for stream in reqs for b in stream}):
        jax.block_until_ready(
            plan.execute_wide(jnp.zeros((m.n, w), jnp.float32)))
    plan.reset_stats()
    sync_lat = []
    t_sync0 = time.perf_counter()
    for s in range(streams):
        for b in reqs[s]:
            t1 = time.perf_counter()
            jax.block_until_ready(plan.execute_wide(b))
            sync_lat.append(time.perf_counter() - t1)
    sync_span = time.perf_counter() - t_sync0
    sync_us = np.asarray(sync_lat) * 1e6
    sync_goodput = len(sync_lat) / max(sync_span, 1e-12)

    print(plan.dispatch.summary())
    print(f"engine serving {args.spmm_structure} [{m.n}x{m.n}, "
          f"nnz={m.nnz}]: {streams} streams x {per_stream} requests, "
          f"widths d={args.spmm_d}/{max(args.spmm_d // 2, 1)}, "
          f"open-loop rate {rate:.0f} req/s/stream, "
          f"queue={args.engine_queue} policy={args.engine_policy}")
    print(f"startup (classify+plan+convert+compile, {warmed} launch "
          f"widths warmed): {startup_s * 1e3:.1f} ms")
    print(engine.summary())
    print(f"sync per-request replay of the same {len(sync_lat)} requests: "
          f"p50={np.percentile(sync_us, 50):.0f}us "
          f"p99={np.percentile(sync_us, 99):.0f}us "
          f"goodput={sync_goodput:.1f} req/s")
    if stats["goodput_rps"] > 0:
        print(f"engine vs sync goodput: {stats['goodput_rps']:.1f} vs "
              f"{sync_goodput:.1f} req/s "
              f"({stats['goodput_rps'] / max(sync_goodput, 1e-12):.2f}x)")


def main():
    """Parse arguments and run either the LM or the streamed-SpMM server."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--spmm-stream", action="store_true",
                    help="serve SpMM through a persistent sparse.plan "
                         "instead of an LM decode loop")
    ap.add_argument("--spmm-structure", choices=STREAM_STRUCTURES,
                    default="moe-block")
    ap.add_argument("--spmm-n", type=int, default=4096)
    ap.add_argument("--spmm-d", type=int, default=64)
    ap.add_argument("--spmm-steps", type=int, default=64,
                    help="requests to serve = the plan's reuse horizon")
    ap.add_argument("--spmm-compare", action="store_true",
                    help="also time per-call dispatch of the same stream")
    ap.add_argument("--spmm-shards", type=int, default=0,
                    help="serve through the sharded tier on this many "
                         "devices (-1 = all visible); on CPU export "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first")
    ap.add_argument("--engine", action="store_true",
                    help="serve through the continuous-batching engine "
                         "(repro.sparse.engine): open-loop concurrent "
                         "clients, bounded queue, coalesced execute_wide "
                         "batches, p50/p99 + goodput report vs a sync "
                         "per-request baseline")
    ap.add_argument("--engine-streams", type=int, default=4,
                    help="concurrent synthetic client streams")
    ap.add_argument("--engine-requests", type=int, default=64,
                    help="total requests across all streams")
    ap.add_argument("--engine-rate", type=float, default=2000.0,
                    help="open-loop arrival rate per stream (requests/s)")
    ap.add_argument("--engine-queue", type=int, default=256,
                    help="bounded admission-queue depth")
    ap.add_argument("--engine-policy", choices=("wait", "shed"),
                    default="wait",
                    help="backpressure when the queue is full: block the "
                         "submitter ('wait') or reject ('shed')")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the on-host ceiling calibration at startup; "
                         "the serving plan then predicts from measured "
                         "(peak_fraction, d_half) instead of defaults")
    args = ap.parse_args()

    if args.calibrate:
        run_startup_calibration()
    if args.engine:
        serve_spmm_engine(args)
        return
    if args.spmm_stream:
        serve_spmm_stream(args)
        return
    if not args.arch:
        ap.error("--arch is required unless --spmm-stream or --engine "
                 "is set")

    from repro.configs.base import get_config
    from repro.models import model as M
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size - 1,
                           size=(args.batch, args.prompt_len)).astype(
        np.int32)
    t0 = time.perf_counter()
    out = generate(cfg, params, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0][:10])


if __name__ == "__main__":
    main()
