"""Serving launcher: batched prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M


def generate(cfg, params, prompts: np.ndarray, gen: int):
    """Greedy decode ``gen`` tokens after prefilling ``prompts`` [B,S]."""
    B, S = prompts.shape
    cache = M.init_cache(cfg, B, S + gen)
    # Prefill by stepping (teacher forcing) — a production server would
    # batch-prefill; the dry-run prefill cells cover that path.
    tok = jnp.asarray(prompts[:, 0])
    step = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    for t in range(S - 1):
        _, cache = step(params, cache, jnp.asarray(prompts[:, t]),
                        jnp.int32(t))
    tok = jnp.asarray(prompts[:, -1])
    out = []
    for t in range(gen):
        logits, cache = step(params, cache, tok, jnp.int32(S - 1 + t))
        tok = jnp.argmax(
            logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size - 1,
                           size=(args.batch, args.prompt_len)).astype(
        np.int32)
    t0 = time.perf_counter()
    out = generate(cfg, params, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0][:10])


if __name__ == "__main__":
    main()
