"""Serving launcher: batched LM decode, plus the streamed-SpMM serving path.

LM serving (prefill + greedy decode with a KV cache):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Streamed SpMM serving (``--spmm-stream``): hold one sparse operator for
the whole process, plan once through ``sparse.plan`` with the expected
request count as the reuse horizon, and serve every per-step right-hand
side through the bound kernel (``docs/serving.md``):

    PYTHONPATH=src python -m repro.launch.serve --spmm-stream \
        --spmm-structure moe-block --spmm-n 4096 --spmm-d 64 \
        --spmm-steps 64

``--spmm-shards N`` serves the same stream through the sharded tier
(``repro.sparse.shard``): the plan partitions the operator across an
N-device mesh and replays under ``shard_map``; the printed summary adds
the B-distribution strategy audit (``docs/sharding.md``):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --spmm-stream \
        --spmm-shards -1 --spmm-structure moe-block

``--calibrate`` runs the on-host compute-ceiling calibration
(``repro.core.calibrate``) at startup and persists it, so the serving
plan predicts from measured ``(peak_fraction, d_half)`` ceilings.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.patterns import serving_suite


def generate(cfg, params, prompts: np.ndarray, gen: int):
    """Greedy decode ``gen`` tokens after prefilling ``prompts`` [B,S]."""
    from repro.models import model as M
    B, S = prompts.shape
    cache = M.init_cache(cfg, B, S + gen)
    # Prefill by stepping (teacher forcing) — a production server would
    # batch-prefill; the dry-run prefill cells cover that path.
    tok = jnp.asarray(prompts[:, 0])
    step = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    for t in range(S - 1):
        _, cache = step(params, cache, jnp.asarray(prompts[:, t]),
                        jnp.int32(t))
    tok = jnp.asarray(prompts[:, -1])
    out = []
    for t in range(gen):
        logits, cache = step(params, cache, tok, jnp.int32(S - 1 + t))
        tok = jnp.argmax(
            logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.stack(out, axis=1)


#: CLI choices derive from the shared registry so they can't drift from it.
STREAM_STRUCTURES = tuple(serving_suite(64))


def build_stream_matrix(structure: str, n: int):
    """Build the served sparse operator for one of the paper structures.

    ``moe-block`` is the serving-path case the repo targets: the MoE
    expert-dispatch matrix — dense t x t blocks on the diagonal, one per
    expert token bucket (repro.models.moe routes tokens into exactly this
    shape; see examples/moe_block_sparse.py).  The rest are the paper's
    Table III regimes at serving scale.  All four come from the shared
    registry ``repro.core.patterns.serving_suite``, which
    ``benchmarks/stream.py`` measures.
    """
    suite = serving_suite(n)
    if structure not in suite:
        raise ValueError(f"unknown structure {structure!r}; choose from "
                         f"{STREAM_STRUCTURES}")
    return suite[structure]()


def run_startup_calibration() -> None:
    """Calibrate the per-format compute ceilings for the serving host.

    Runs the short ``repro.core.calibrate`` sweep against the hardware
    spec the default dispatcher resolves to, persists the result to the
    default :class:`~repro.core.calibrate.CalibrationStore`, and
    refreshes the dispatcher so every subsequent plan (including the
    ``--spmm-stream`` serving plan) predicts from measured ceilings
    (``ceiling_source="calibrated"``) instead of the baked-in defaults.
    """
    from repro import sparse
    from repro.core.calibrate import CalibrationStore, calibrate

    disp = sparse.default_dispatcher()
    backend = disp._resolve_backend()
    hw = disp._resolve_hardware(backend)
    t0 = time.perf_counter()
    store = CalibrationStore()
    cal = calibrate(hw, backend=backend, store=store)
    disp.refresh_calibration()
    print(f"startup calibration ({backend} kernels on {hw.name}) took "
          f"{time.perf_counter() - t0:.1f}s -> {store.path_for(hw, backend)}")
    print(cal.summary())


def serve_spmm_stream(args) -> None:
    """Serve ``--spmm-steps`` right-hand sides through one persistent plan."""
    from repro import sparse
    m = build_stream_matrix(args.spmm_structure, args.spmm_n)
    rng = np.random.default_rng(1)

    def next_batch():
        return jnp.asarray(
            rng.normal(size=(m.n, args.spmm_d)).astype(np.float32))

    mesh = None
    shards = getattr(args, "spmm_shards", 0)    # absent on hand-built args
    if shards:
        from repro.launch.mesh import make_shard_mesh
        mesh = make_shard_mesh(None if shards < 0 else shards)

    t0 = time.perf_counter()
    plan = sparse.plan(m, sparse.BSpec(d=args.spmm_d, reuse=args.spmm_steps),
                       mesh=mesh)
    jax.block_until_ready(plan.execute(next_batch()))   # bind + compile
    startup_s = time.perf_counter() - t0
    plan.reset_stats()     # the warm-up is startup, not a served request

    lat = []
    for _ in range(args.spmm_steps):
        b = next_batch()
        t1 = time.perf_counter()
        jax.block_until_ready(plan.execute(b))
        lat.append(time.perf_counter() - t1)
    lat_us = np.asarray(lat) * 1e6
    flops = 2.0 * m.nnz * args.spmm_d

    # ShardedPlan.summary() adds the B-strategy audit under the format
    # decision table; the single-device plan prints the table alone.
    print(plan.summary() if mesh is not None else plan.dispatch.summary())
    single = sparse.plan_spmm(m, args.spmm_d, reuse=1)
    note = ("same as single-shot" if single.chosen == plan.chosen else
            f"single-shot would pick {single.chosen}")
    print(f"serving {args.spmm_structure} [{m.n}x{m.n}, nnz={m.nnz}] "
          f"d={args.spmm_d}: planned for reuse={args.spmm_steps} "
          f"-> {plan.chosen} ({note})")
    print(f"startup (classify+plan+convert+compile): {startup_s * 1e3:.1f} ms")
    print(f"steady-state: p50={np.percentile(lat_us, 50):.0f}us "
          f"p99={np.percentile(lat_us, 99):.0f}us "
          f"-> {flops / np.median(lat_us) / 1e3:.2f} GFLOP/s")

    if args.spmm_compare:
        # Replay the exact same stream: reseed so the draws repeat the
        # streamed run (one warm-up batch, then the served batches).
        rng = np.random.default_rng(1)
        # Warm the single-shot format's kernel first: it can differ from
        # the streamed choice, and its one-time jit compile would
        # otherwise land inside the first timed iteration.
        jax.block_until_ready(
            sparse.Dispatcher(backend=plan.dispatch.backend)
            .spmm(m, next_batch(), reuse=1))
        # Time only the dispatch+execute, like the streamed loop above —
        # host-side RHS generation is excluded from both sides.
        percall_s = 0.0
        for _ in range(args.spmm_steps):
            b = next_batch()
            t2 = time.perf_counter()
            jax.block_until_ready(
                sparse.Dispatcher(backend=plan.dispatch.backend)
                .spmm(m, b, reuse=1))
            percall_s += time.perf_counter() - t2
        streamed_s = float(np.sum(lat))
        print(f"per-call dispatch (fresh dispatcher per request, no "
              f"caches) of the same stream: {percall_s * 1e3:.1f} ms vs "
              f"streamed {streamed_s * 1e3:.1f} ms "
              f"({percall_s / max(streamed_s, 1e-12):.1f}x; "
              f"a warm-cache per-call baseline sits between — see "
              f"benchmarks/stream.py percall_cached)")
    print(f"stats: {plan.stats()}")


def main():
    """Parse arguments and run either the LM or the streamed-SpMM server."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--spmm-stream", action="store_true",
                    help="serve SpMM through a persistent sparse.plan "
                         "instead of an LM decode loop")
    ap.add_argument("--spmm-structure", choices=STREAM_STRUCTURES,
                    default="moe-block")
    ap.add_argument("--spmm-n", type=int, default=4096)
    ap.add_argument("--spmm-d", type=int, default=64)
    ap.add_argument("--spmm-steps", type=int, default=64,
                    help="requests to serve = the plan's reuse horizon")
    ap.add_argument("--spmm-compare", action="store_true",
                    help="also time per-call dispatch of the same stream")
    ap.add_argument("--spmm-shards", type=int, default=0,
                    help="serve through the sharded tier on this many "
                         "devices (-1 = all visible); on CPU export "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the on-host ceiling calibration at startup; "
                         "the serving plan then predicts from measured "
                         "(peak_fraction, d_half) instead of defaults")
    args = ap.parse_args()

    if args.calibrate:
        run_startup_calibration()
    if args.spmm_stream:
        serve_spmm_stream(args)
        return
    if not args.arch:
        ap.error("--arch is required unless --spmm-stream is set")

    from repro.configs.base import get_config
    from repro.models import model as M
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size - 1,
                           size=(args.batch, args.prompt_len)).astype(
        np.int32)
    t0 = time.perf_counter()
    out = generate(cfg, params, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0][:10])


if __name__ == "__main__":
    main()
