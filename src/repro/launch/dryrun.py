"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as a fresh process (``python -m repro.launch.dryrun``): the
first two lines force 512 host platform devices before jax initializes.
Smoke tests and benchmarks run in normal processes and see 1 device.

Per cell this:
  1. builds the production mesh (16x16 or 2x16x16),
  2. lowers the train/prefill/serve step with abstract ShapeDtypeStruct
     inputs (zero allocation),
  3. compiles, prints memory_analysis() and cost_analysis(),
  4. parses collective bytes out of the post-SPMD HLO text,
  5. writes a JSON record for the roofline analyzer (core.analyzer).

``--all`` runs every runnable cell in subprocesses (isolation against
compiler memory growth; already-written records are skipped, so the sweep
is resumable).
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402  (env var must precede any jax import)
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, all_cells, get_config
from repro.core import hlo_analysis as H
from repro.core import hlo_flops as HF
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import moe as MOE
from repro.train import train_step as TS

DEFAULT_OUT = "experiments/dryrun"

# Baseline per-arch training config required to fit the 16 GiB/chip v5e
# budget on the 256-chip pod (documented in EXPERIMENTS.md Section Dry-run).
# grad_accum trades step latency for activation memory; the qwen3 MoE cell
# additionally keeps AdamW moments in bf16 (235B params x fp32 triple would
# need 11 GiB/chip for optimizer state alone).
GRAD_ACCUM_DEFAULTS = {
    ("qwen2-72b", "train_4k"): 8,
    ("qwen3-moe-235b-a22b", "train_4k"): 8,
    ("gemma3-12b", "train_4k"): 4,
    ("falcon-mamba-7b", "train_4k"): 2,
    ("recurrentgemma-9b", "train_4k"): 8,
}
OPT_DTYPE_DEFAULTS = {
    "qwen3-moe-235b-a22b": "bfloat16",
}


def input_specs(cfg, shape):
    """Abstract (ShapeDtypeStruct) stand-ins for every model input."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            n_mm = min(s // 4, 1024)
            specs["mm_embeds"] = jax.ShapeDtypeStruct(
                (b, n_mm, cfg.d_model), jnp.float32)
            specs["positions_3d"] = jax.ShapeDtypeStruct((3, b, s), i32)
        return specs
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


def abstract_state(cfg, shape, kind):
    """Abstract (shape-only) params + decode cache via ``jax.eval_shape``."""
    params = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    if kind != "decode":
        return params, None
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    return params, cache


def sparse_components(cfg, shape):
    """Paper-model metadata attached to the record (DESIGN.md Section 6)."""
    out = []
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)
    if cfg.num_experts:
        out.append(MOE.sparse_component_spec(cfg, shape, tokens))
    if "local" in cfg.layer_pattern:
        w = min(cfg.window_size, shape.seq_len)
        out.append({
            "name": f"local_attention/{cfg.name}",
            "regime": "diagonal",
            "n": shape.seq_len,
            "nnz": shape.seq_len * w,
            "d": cfg.num_heads * cfg.head_dim,
            "sizeof_val": 2,
        })
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             grad_accum: int = 0, verbose: bool = True,
             causal_impl: str = "masked",
             chunked_loss: bool = False) -> dict:
    """Trace one (arch, shape, mesh) cell and return its dry-run record.

    Compiles nothing and allocates no real arrays: the step function is
    traced over abstract state on a production mesh, and the record
    carries the HLO cost analysis plus the sparse-component metadata the
    roofline analyzer consumes (``benchmarks/run.py`` roofline section).
    """
    from repro.models import attention as ATT
    ATT.set_causal_impl(causal_impl)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if grad_accum <= 0:
        grad_accum = GRAD_ACCUM_DEFAULTS.get((arch, shape_name), 1)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)

    t0 = time.time()
    with mesh:
        params_abs, cache_abs = abstract_state(cfg, shape, shape.kind)
        specs = input_specs(cfg, shape)
        if shape.kind == "train":
            from repro.optim import adamw
            opt_cfg = adamw.AdamWConfig(
                state_dtype=OPT_DTYPE_DEFAULTS.get(arch, "float32"))
            step, _ = TS.make_train_step(cfg, shape, mesh,
                                         opt_cfg=opt_cfg,
                                         grad_accum=grad_accum,
                                         chunked_loss=chunked_loss)
            opt_abs = jax.eval_shape(
                lambda p: adamw.init_state(p, opt_cfg), params_abs)
            step_abs = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = step.lower(params_abs, opt_abs, specs, step_abs)
        elif shape.kind == "prefill":
            step, _ = TS.make_prefill_step(cfg, shape, mesh)
            lowered = step.lower(params_abs, specs)
        else:
            step, _ = TS.make_serve_step(cfg, shape, mesh)
            lowered = step.lower(params_abs, cache_abs, specs["tokens"],
                                 specs["pos"])
        compiled = lowered.compile()
        mem = H.memory_summary(compiled)
        cost_raw = H.cost_summary(compiled)
        hlo_text = compiled.as_text()
        # Loop-aware re-count: XLA's cost_analysis counts while bodies once;
        # scan-heavy programs need trip-count multipliers (core.hlo_flops).
        loop_aware = HF.analyze_hlo(hlo_text)
        cost = {"flops_per_device": loop_aware["flops"],
                "bytes_per_device": loop_aware["bytes_accessed"]}
        coll = loop_aware["collective_bytes"]
        counts = loop_aware["collective_counts"]
        if verbose:
            print(f"--- {arch} / {shape_name} / {mesh_name} ---")
            print("memory_analysis:", compiled.memory_analysis())
            print("cost_analysis (raw, loops-once) flops=%.4g bytes=%.4g"
                  % (cost_raw["flops_per_device"],
                     cost_raw["bytes_per_device"]))
            print("loop-aware flops=%.4g bytes=%.4g"
                  % (cost["flops_per_device"], cost["bytes_per_device"]))
            print("collective bytes/device:", {k: f"{v:.3g}"
                                               for k, v in coll.items()})

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "step_kind": shape.kind,
        "grad_accum": grad_accum,
        "causal_impl": causal_impl,
        "chunked_loss": chunked_loss,
        "cost": cost,
        "cost_raw": cost_raw,
        "memory": mem,
        "collectives": coll,
        "collective_counts": counts,
        "model_flops": cfg.model_flops(shape),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.param_count(active=True),
        "sparse_components": sparse_components(cfg, shape),
        "compile_seconds": time.time() - t0,
    }
    return record


def record_path(out_dir, arch, shape_name, multi_pod):
    """Path the dry-run record for one cell is written to / read from."""
    tag = "pod2" if multi_pod else "pod1"
    return os.path.join(out_dir, f"{arch}__{shape_name}__{tag}.json")


def main():
    """Run one dry-run cell (or --all) and write the JSON records."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--causal-impl", default="masked",
                    choices=("masked", "triangle"))
    ap.add_argument("--chunked-loss", action="store_true")
    ap.add_argument("--out-dir", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    if args.all:
        failures = []
        for arch, shape_name in all_cells():
            for multi_pod in (False, True):
                path = record_path(args.out_dir, arch, shape_name,
                                   multi_pod)
                if os.path.exists(path) and not args.force:
                    print("skip (exists):", path)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--out-dir", args.out_dir]
                if multi_pod:
                    cmd.append("--multi-pod")
                print(">>>", " ".join(cmd), flush=True)
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append((arch, shape_name, multi_pod))
        if failures:
            print("FAILED cells:", failures)
            sys.exit(1)
        print("all cells OK")
        return

    assert args.arch and args.shape, "--arch/--shape or --all required"
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod,
                       grad_accum=args.grad_accum,
                       causal_impl=args.causal_impl,
                       chunked_loss=args.chunked_loss)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    path = record_path(args.out_dir, args.arch, args.shape, args.multi_pod)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
