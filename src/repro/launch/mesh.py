"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the first
jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real host devices (tests / smoke runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def make_shard_mesh(num_shards: int | None = None):
    """1-D mesh for the sharded SpMM tier (``repro.sparse.shard``).

    Args:
        num_shards: devices to use; defaults to all available.  On CPU,
            export ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
            *before* the first jax call to get 8 virtual devices.

    Returns:
        A ``("shard",)`` mesh over the first ``num_shards`` devices.

    Raises:
        ValueError: if more shards are requested than devices exist.
    """
    devices = jax.devices()
    if num_shards is None:
        num_shards = len(devices)
    if num_shards > len(devices):
        raise ValueError(
            f"requested {num_shards} shards but only {len(devices)} "
            f"devices are visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N on CPU)")
    return jax.make_mesh((num_shards,), ("shard",),
                         devices=devices[:num_shards])
