"""repro.launch"""
