"""Sharding policy: parameter PartitionSpecs, activation rules, batch specs.

Scheme (DESIGN.md Section 4):
  * weights: Megatron TP over "model" (column-parallel into the layer,
    row-parallel out of it) + FSDP over "data" on the other dim; replicated
    across "pod" (hybrid ZeRO: cross-pod traffic is gradients only, which is
    where the int8 compression applies).
  * activations: batch over ("pod","data"); attention heads over "model"
    when the head count divides TP, else sequence/context-parallel fallback;
    FFN hidden and vocab logits over "model".
  * decode KV caches: batch over whatever data axes divide it, *sequence*
    over "model" (+ leftover data axes) — uniform across every arch
    regardless of head counts, which is what makes the long_500k cells
    shardable (a 512k-token cache is split into per-chip 1-2k slices).

Everything is expressed as PartitionSpec trees; NamedShardings are built at
jit boundaries by the launch layer.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# Parameter rules: path regex -> spec builder(data_axis).
# Stacked layer leaves get a leading group dim (None) prepended.
# ---------------------------------------------------------------------------

_COL = ("wqkv", "wq", "wk", "wv", "wi_fused", "wi_gate", "wi_up", "wi",
        "in_proj", "wx", "wy", "dt_proj", "lm_head", "mm_proj")
_ROW = ("wo", "out", "out_proj", "x_proj")

_PARAM_RULES = [
    (re.compile(r"embed/table$"), lambda d: P("model", None)),
    (re.compile(r"(%s)/kernel$" % "|".join(_COL)), lambda d: P(d, "model")),
    (re.compile(r"(%s)/kernel$" % "|".join(_ROW)), lambda d: P("model", d)),
    (re.compile(r"(%s)/bias$" % "|".join(_COL)), lambda d: P("model")),
    (re.compile(r"(%s)/bias$" % "|".join(_ROW)), lambda d: P()),
    (re.compile(r"router/kernel$"), lambda d: P()),
    (re.compile(r"conv_w$"), lambda d: P(None, "model")),
    (re.compile(r"conv_b$"), lambda d: P("model")),
    (re.compile(r"A_log$"), lambda d: P("model", None)),
    (re.compile(r"(D|lam)$"), lambda d: P("model")),
    (re.compile(r"w_[ri]$"), lambda d: P("model", None, None)),
    (re.compile(r"w_gate$"), lambda d: P("model", d, None)),
    (re.compile(r"w_up$"), lambda d: P("model", d, None)),
    (re.compile(r"w_down$"), lambda d: P("model", None, d)),
]


def validate_spec(spec: P, shape, mesh) -> P:
    """Drop sharding on any dim the mesh axes do not evenly divide.

    Keeps the policy total (e.g. whisper's odd 51865 vocab falls back to a
    replicated vocab dim instead of failing the lower).
    """
    out = []
    for i, axes in enumerate(tuple(spec)):
        if axes is None or i >= len(shape):
            out.append(None if i >= len(shape) else axes)
            continue
        axes_t = axes if isinstance(axes, tuple) else (axes,)
        factor = 1
        for a in axes_t:
            factor *= mesh.shape[a]
        out.append(axes if shape[i] % factor == 0 else None)
    while len(out) < len(shape):
        out.append(None)
    return P(*out)


def _param_spec(path: str, shape, stacked: bool, mesh) -> P:
    ndim = len(shape)
    for rx, builder in _PARAM_RULES:
        if rx.search(path):
            spec = builder("data")
            if stacked:
                spec = P(*((None,) + tuple(spec)))
            if len(spec) < ndim:
                spec = P(*(tuple(spec) + (None,) * (ndim - len(spec))))
            return validate_spec(spec, shape, mesh)
    return P(*((None,) * ndim))


def param_pspecs(cfg: ModelConfig, params_shape, mesh) -> Dict:
    """PartitionSpec tree matching a params pytree (shapes or arrays)."""
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    treedef = jax.tree_util.tree_structure(params_shape)
    specs = []
    for path, leaf in flat:
        spath = "/".join(
            p.key if hasattr(p, "key") else str(p) for p in path)
        stacked = spath.startswith("layers/") or "/layers/" in spath
        specs.append(_param_spec(spath, leaf.shape, stacked, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch / activation rules.
# ---------------------------------------------------------------------------

def dp_axes_for_batch(mesh, batch: int) -> Tuple[Tuple[str, ...],
                                                 Tuple[str, ...]]:
    """Greedy: batch takes ("pod","data") axes whose product divides it;
    the leftover axes are free for sequence sharding."""
    taken, leftover = [], []
    prod = 1
    for ax in ("pod", "data"):
        if ax not in mesh.axis_names:
            continue
        size = mesh.shape[ax]
        if batch % (prod * size) == 0:
            taken.append(ax)
            prod *= size
        else:
            leftover.append(ax)
    return tuple(taken), tuple(leftover)


def _maybe(axes: Tuple[str, ...]):
    return axes if axes else None


def activation_rules(cfg: ModelConfig, mesh, shape: ShapeConfig) -> Dict:
    """Rules dict for ShardingCtx, keyed by semantic activation kind."""
    tp = mesh.shape["model"]
    if shape.kind == "decode":
        dp, rest = dp_axes_for_batch(mesh, shape.global_batch)
        seq_axes = tuple(rest) + ("model",)
        return {
            "tokens_bse": P(_maybe(dp), None, None),
            "kv_cache": P(_maybe(dp), seq_axes, None, None),
        }
    dp, _ = dp_axes_for_batch(mesh, shape.global_batch)
    dp = _maybe(dp)
    heads_ok = cfg.num_heads and cfg.num_heads % tp == 0
    kv_ok = cfg.num_kv_heads and cfg.num_kv_heads % tp == 0
    rules = {
        # Megatron sequence parallelism: the residual stream between layers
        # is sequence-sharded over "model" (all-gathered at layer entry,
        # reduce-scattered at exit) so saved remat carries scale 1/TP.
        # validate() in ShardingCtx drops it when seq doesn't divide.
        "tokens_bse": P(dp, "model", None),
        "ffn_bsf": P(dp, None, "model"),
        "logits_bsv": P(dp, None, "model"),
        "ssm_bsdn": P(dp, None, "model"),
        "moe_gecd": P(dp, "model", None, None),
    }
    if heads_ok:
        rules["heads_bshd"] = P(dp, None, "model", None)
    else:
        # context-parallel fallback: shard query sequence instead of heads
        rules["heads_bshd"] = P(dp, "model", None, None)
    if kv_ok:
        rules["kv_bskd"] = P(dp, None, "model", None)
    return rules


def batch_pspecs(cfg: ModelConfig, mesh, shape: ShapeConfig) -> Dict:
    """PartitionSpecs for one batch's arrays (tokens/labels/modalities)."""
    dp, _ = dp_axes_for_batch(mesh, shape.global_batch)
    dp = _maybe(dp)
    specs = {"tokens": P(dp, None)}
    if shape.kind == "train":
        specs["labels"] = P(dp, None)
    if cfg.family == "encdec":
        specs["frames"] = P(dp, None, None)
    if cfg.family == "vlm":
        specs["mm_embeds"] = P(dp, None, None)
        specs["positions_3d"] = P(None, dp, None)
    return specs


def cache_pspecs(cfg: ModelConfig, mesh, shape: ShapeConfig,
                 cache_shape) -> Dict:
    """Specs for the decode cache pytree (leaves carry a leading group dim).

    KV leaves [G,B,S,H,D]: batch over dividing data axes, seq over the rest
    + "model".  Recurrent states [G,B,...]: batch over data axes, feature
    over "model".
    """
    dp, rest = dp_axes_for_batch(mesh, shape.global_batch)
    dp = _maybe(dp)
    seq_axes = tuple(rest) + ("model",)

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "cross_k", "cross_v"):
            return P(None, dp, seq_axes, None, None)
        if name == "conv":            # [G,B,K-1,C]
            return P(None, dp, None, "model")
        if name == "h":               # [G,B,rw] or [G,B,d_in,N]
            if leaf.ndim == 4:
                return P(None, dp, "model", None)
            return P(None, dp, "model")
        return P(*((None,) * leaf.ndim))

    flat = jax.tree_util.tree_flatten_with_path(cache_shape)[0]
    treedef = jax.tree_util.tree_structure(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [validate_spec(spec_for(p, l), l.shape, mesh)
                  for p, l in flat])


# ---------------------------------------------------------------------------
# NamedSharding helpers.
# ---------------------------------------------------------------------------

def named(mesh, spec_tree):
    """Map a PartitionSpec tree to ``NamedSharding``s on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def opt_state_pspecs(param_specs: Dict) -> Dict:
    """AdamW state: mu/nu inherit the param spec; count replicated."""
    return {"mu": param_specs, "nu": param_specs, "count": P()}
