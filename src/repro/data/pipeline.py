"""Deterministic, stateless-seeded data pipeline.

``batch_for_step(step)`` is a pure function of (seed, step, shape), so
checkpoint/restart and elastic resharding never replay or skip data: a
restarted trainer resumes at step k and regenerates exactly the batch the
failed run would have seen.  Batches are produced host-side (numpy) and
device_put with the step's sharding by the trainer.

Two sources:
  synthetic  zipf-distributed token ids (heavy-tailed like real text)
  memmap     flat token file (binary uint16/uint32) sampled by stateless
             offsets — the production path for real corpora
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32_000
    zipf_a: float = 1.2
    path: Optional[str] = None      # memmap token file (None => synthetic)
    token_dtype: str = "uint16"


class Pipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data = dataclasses.replace(data, vocab_size=cfg.vocab_size)
        self._tokens = None
        if data.path:
            self._tokens = np.memmap(data.path, dtype=data.token_dtype,
                                     mode="r")

    # ------------------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step]))

    def _synthetic_tokens(self, rng, shape) -> np.ndarray:
        # Zipf sampling clipped into the vocab (heavy-tailed id frequency).
        raw = rng.zipf(self.data.zipf_a, size=shape)
        return (raw % (self.data.vocab_size - 2) + 2).astype(np.int32)

    def _memmap_tokens(self, rng, batch: int, seq: int) -> np.ndarray:
        n = self._tokens.shape[0] - (seq + 1)
        starts = rng.integers(0, n, size=batch)
        out = np.stack([self._tokens[s:s + seq + 1] for s in starts])
        return out.astype(np.int32)

    # ------------------------------------------------------------------
    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        """Training batch: tokens + next-token labels (+ modality stubs)."""
        rng = self._rng(step)
        b, s = self.shape.global_batch, self.shape.seq_len
        if self._tokens is not None:
            seqs = self._memmap_tokens(rng, b, s)
        else:
            seqs = self._synthetic_tokens(rng, (b, s + 1))
        batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        batch.update(self.modality_stubs(rng, b, s))
        return batch

    def modality_stubs(self, rng, b: int, s: int) -> Dict[str, np.ndarray]:
        """Frontend stubs per the assignment: precomputed frame/patch
        embeddings for [audio]/[vlm] archs."""
        cfg = self.cfg
        out: Dict[str, np.ndarray] = {}
        if cfg.family == "encdec":
            out["frames"] = rng.normal(
                size=(b, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            n_mm = min(s // 4, 1024)
            out["mm_embeds"] = rng.normal(
                size=(b, n_mm, cfg.d_model)).astype(np.float32)
            # M-RoPE 3D positions: temporal / height / width streams.
            t_pos = np.tile(np.arange(s, dtype=np.int32), (b, 1))
            grid = int(np.sqrt(max(n_mm, 1)))
            h_pos = t_pos.copy()
            w_pos = t_pos.copy()
            if grid > 0:
                hw = np.arange(n_mm, dtype=np.int32)
                h_pos[:, :n_mm] = hw // max(grid, 1)
                w_pos[:, :n_mm] = hw % max(grid, 1)
            out["positions_3d"] = np.stack([t_pos, h_pos, w_pos])
        return out
