"""DLMC/SuiteSparse-style real-matrix corpus layer.

Every benchmark and claim gate in this repo historically ran on the
synthetic generators in ``repro.core.patterns``; the source paper's whole
point is that *real* SuiteSparse-grouped structures (block, banded,
scale-free, uniform) are what break any single roofline model.  This
module is the dataset layer that closes that gap:

  loaders     ``load_smtx`` (the DLMC ``.smtx`` CSR-text format used by
              pytorch's ``benchmarks/sparse/dlmc`` suite) and
              ``load_mtx`` (Matrix Market coordinate format, the
              SuiteSparse interchange format); both return the repo's
              native ``COOMatrix``, square-padded when the source is
              rectangular.
  corpus      ``corpus_entries()`` enumerates the active corpus — the
              directory named by ``$REPRO_CORPUS_DIR`` when set, else
              the small vendored sample set shipped inside the package
              (``corpus_samples/``, all four paper groups) so CI and
              tests never touch the network.
  downloader  ``download(url, dest)`` is *opt-in*: hermetic by default,
              it refuses to open a socket unless
              ``$REPRO_CORPUS_ALLOW_DOWNLOAD=1`` (or ``allow=True``) —
              a deliberate guard so no test or CI lane can depend on
              network reachability by accident.

File naming carries the paper group: ``<group>__<name>.smtx|.mtx`` with
``group`` one of :data:`GROUPS`.  ``repro.core.patterns.fit_generator``
turns a corpus matrix's measured statistics back into a synthetic
generator, so benchmark sweeps can scale a real structure up to
out-of-cache sizes.  See ``docs/corpus.md``.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.patterns import COOMatrix

#: The paper's four structure groups; corpus filenames are
#: ``<group>__<name>.<ext>`` and the classifier golden tests assert each
#: vendored matrix lands in its filename's group.
GROUPS: Tuple[str, ...] = ("random", "diagonal", "blocked", "scale_free")

#: The vendored sample set shipped with the package (hermetic CI corpus).
SAMPLES_DIR = pathlib.Path(__file__).resolve().parent / "corpus_samples"

#: Loader dispatch by suffix.
_SUFFIXES = (".smtx", ".mtx")


class CorpusDownloadDisabled(RuntimeError):
    """Raised when ``download`` is called without the opt-in flag."""


def _finalize_loaded(n: int, rows: np.ndarray, cols: np.ndarray,
                     vals: np.ndarray, pattern: str,
                     meta: dict) -> COOMatrix:
    """Sort row-major, deduplicate (first value wins), keep real values."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    if np.any((rows < 0) | (rows >= n) | (cols < 0) | (cols >= n)):
        raise ValueError(f"{meta.get('source', 'corpus matrix')}: index "
                         f"out of range for n={n}")
    lin = rows * n + cols
    order = np.argsort(lin, kind="stable")
    lin, vals = lin[order], vals[order]
    keep = np.concatenate([[True], np.diff(lin) > 0])
    lin, vals = lin[keep], vals[keep]
    return COOMatrix(n=n, rows=(lin // n).astype(np.int32),
                     cols=(lin % n).astype(np.int32), vals=vals,
                     pattern=pattern, meta=meta)


def _synth_vals(nnz: int, seed: int = 0) -> np.ndarray:
    """Deterministic values for pattern-only sources (no stored values)."""
    rng = np.random.default_rng(seed)
    return rng.uniform(0.5, 1.5, size=nnz)


def load_smtx(path: os.PathLike, pattern: str = "corpus") -> COOMatrix:
    """Load a DLMC ``.smtx`` file (CSR text: shape line, ptr line, col line).

    The format (pytorch ``benchmarks/sparse/dlmc``): line 1 is
    ``nrows, ncols, nnz`` (comma separated), line 2 the ``nrows + 1``
    row pointers, line 3 the ``nnz`` column indices.  DLMC stores
    patterns only, so values are synthesized deterministically.
    Rectangular sources are square-padded to ``n = max(nrows, ncols)``
    (the repo's SpMM stack is square); the true shape is kept in
    ``meta``.

    Args:
        path: the ``.smtx`` file.
        pattern: the ``COOMatrix.pattern`` tag to attach.

    Returns:
        The matrix as a sorted, deduplicated ``COOMatrix``.

    Raises:
        ValueError: on a malformed header, pointer, or index section.
    """
    path = pathlib.Path(path)
    text = path.read_text(encoding="utf-8").strip().splitlines()
    if len(text) < 2:
        raise ValueError(f"{path.name}: expected 3 lines (shape, row "
                         f"pointers, column indices), got {len(text)}")
    try:
        nrows, ncols, nnz = (int(tok) for tok in text[0].replace(
            ",", " ").split())
    except ValueError:
        raise ValueError(f"{path.name}: malformed shape line "
                         f"{text[0]!r}") from None
    ptr = np.array(text[1].split(), dtype=np.int64)
    cols = (np.array(text[2].split(), dtype=np.int64)
            if len(text) > 2 and text[2].strip() else
            np.zeros(0, dtype=np.int64))
    if ptr.shape[0] != nrows + 1 or ptr[0] != 0 or ptr[-1] != nnz:
        raise ValueError(f"{path.name}: row-pointer line inconsistent "
                         f"with shape header ({ptr.shape[0]} ptrs, "
                         f"expected {nrows + 1}; ptr[-1]="
                         f"{ptr[-1] if ptr.size else 'none'} vs nnz={nnz})")
    if cols.shape[0] != nnz:
        raise ValueError(f"{path.name}: {cols.shape[0]} column indices, "
                         f"header says nnz={nnz}")
    rows = np.repeat(np.arange(nrows, dtype=np.int64), np.diff(ptr))
    n = max(nrows, ncols)
    meta = {"source": path.name, "format": "smtx",
            "nrows": nrows, "ncols": ncols}
    return _finalize_loaded(n, rows, cols, _synth_vals(nnz), pattern, meta)


def load_mtx(path: os.PathLike, pattern: str = "corpus") -> COOMatrix:
    """Load a Matrix Market coordinate file (SuiteSparse interchange).

    Supports ``real`` / ``integer`` / ``pattern`` fields and the
    ``general`` / ``symmetric`` symmetries (symmetric entries are
    mirrored; the diagonal is not duplicated).  Indices are 1-based per
    the spec.  Rectangular sources are square-padded to
    ``n = max(nrows, ncols)``.

    Args:
        path: the ``.mtx`` file.
        pattern: the ``COOMatrix.pattern`` tag to attach.

    Returns:
        The matrix as a sorted, deduplicated ``COOMatrix``.

    Raises:
        ValueError: on a malformed banner, an unsupported field or
            symmetry, or an entry-count mismatch.
    """
    path = pathlib.Path(path)
    with open(path, encoding="utf-8") as f:
        banner = f.readline().strip().lower().split()
        if (len(banner) < 5 or banner[0] != "%%matrixmarket"
                or banner[2] != "coordinate"):
            raise ValueError(f"{path.name}: unsupported MatrixMarket "
                             f"banner {' '.join(banner)!r} (only "
                             f"'matrix coordinate' is supported)")
        field, symmetry = banner[3], banner[4]
        if field not in ("real", "integer", "pattern"):
            raise ValueError(f"{path.name}: unsupported field {field!r}")
        if symmetry not in ("general", "symmetric"):
            raise ValueError(f"{path.name}: unsupported symmetry "
                             f"{symmetry!r}")
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        try:
            nrows, ncols, nnz = (int(tok) for tok in line.split())
        except ValueError:
            raise ValueError(f"{path.name}: malformed size line "
                             f"{line!r}") from None
        body = np.array(f.read().split(), dtype=np.float64)
    per = 2 if field == "pattern" else 3
    if body.shape[0] != per * nnz:
        raise ValueError(f"{path.name}: {body.shape[0] // per} entries, "
                         f"size line says {nnz}")
    body = body.reshape(nnz, per)
    rows = body[:, 0].astype(np.int64) - 1
    cols = body[:, 1].astype(np.int64) - 1
    vals = body[:, 2] if per == 3 else _synth_vals(nnz)
    if symmetry == "symmetric":
        off = rows != cols
        rows, cols = (np.concatenate([rows, cols[off]]),
                      np.concatenate([cols, rows[off]]))
        vals = np.concatenate([vals, vals[off]])
    n = max(nrows, ncols)
    meta = {"source": path.name, "format": "mtx",
            "nrows": nrows, "ncols": ncols, "symmetry": symmetry}
    return _finalize_loaded(n, rows, cols, vals, pattern, meta)


def load_matrix(path: os.PathLike, pattern: str = "corpus") -> COOMatrix:
    """Load ``path`` by suffix (``.smtx`` or ``.mtx``)."""
    path = pathlib.Path(path)
    if path.suffix == ".smtx":
        return load_smtx(path, pattern)
    if path.suffix == ".mtx":
        return load_mtx(path, pattern)
    raise ValueError(f"unknown corpus suffix {path.suffix!r} for "
                     f"{path.name}; expected one of {_SUFFIXES}")


def write_smtx(m: COOMatrix, path: os.PathLike) -> pathlib.Path:
    """Write ``m`` as a DLMC ``.smtx`` pattern file (values dropped)."""
    path = pathlib.Path(path)
    ptr = m.row_ptr()
    lines = [f"{m.n}, {m.n}, {m.nnz}",
             " ".join(str(int(p)) for p in ptr),
             " ".join(str(int(c)) for c in m.cols)]
    path.write_text("\n".join(lines) + "\n", encoding="utf-8")
    return path


def write_mtx(m: COOMatrix, path: os.PathLike, *,
              values: bool = True) -> pathlib.Path:
    """Write ``m`` as a Matrix Market coordinate file (1-based, general)."""
    path = pathlib.Path(path)
    field = "real" if values else "pattern"
    out = [f"%%MatrixMarket matrix coordinate {field} general",
           f"% written by repro.data.corpus ({m.pattern})",
           f"{m.n} {m.n} {m.nnz}"]
    if values:
        out += [f"{r + 1} {c + 1} {v:.6g}"
                for r, c, v in zip(m.rows, m.cols, m.vals)]
    else:
        out += [f"{r + 1} {c + 1}" for r, c in zip(m.rows, m.cols)]
    path.write_text("\n".join(out) + "\n", encoding="utf-8")
    return path


@dataclasses.dataclass(frozen=True)
class CorpusEntry:
    """One corpus matrix: its paper group and where it loads from."""

    name: str          # file stem after the group prefix
    group: str         # one of GROUPS
    path: pathlib.Path

    def load(self) -> COOMatrix:
        """Load the matrix; ``pattern`` is tagged with the group."""
        m = load_matrix(self.path, pattern=self.group)
        return dataclasses.replace(m, meta={**m.meta, "group": self.group,
                                            "corpus_name": self.name})


def _scan(root: pathlib.Path) -> Tuple[CorpusEntry, ...]:
    entries = []
    for path in sorted(root.glob("*")):
        if path.suffix not in _SUFFIXES or "__" not in path.stem:
            continue
        group, name = path.stem.split("__", 1)
        if group not in GROUPS:
            raise ValueError(f"corpus file {path.name}: group {group!r} "
                             f"not in {GROUPS}")
        entries.append(CorpusEntry(name=name, group=group, path=path))
    return tuple(entries)


def vendored_entries() -> Tuple[CorpusEntry, ...]:
    """The sample set shipped inside the package (no network, ever)."""
    return _scan(SAMPLES_DIR)


def corpus_entries(
        root: Optional[os.PathLike] = None) -> Tuple[CorpusEntry, ...]:
    """Enumerate the active corpus.

    Resolution order: an explicit ``root`` argument, then the directory
    named by ``$REPRO_CORPUS_DIR`` (the opt-in hook for a real
    downloaded DLMC/SuiteSparse tree), then the vendored sample set.
    Files must follow the ``<group>__<name>.smtx|.mtx`` convention;
    anything else in the directory is ignored.

    Args:
        root: optional corpus directory override.

    Returns:
        The discovered :class:`CorpusEntry` tuple (possibly empty for an
        empty override directory — never empty for the vendored set).
    """
    root = root or os.environ.get("REPRO_CORPUS_DIR")
    if root:
        return _scan(pathlib.Path(root))
    return vendored_entries()


def download(url: str, dest: os.PathLike, *,
             allow: Optional[bool] = None,
             timeout: float = 60.0) -> pathlib.Path:
    """Fetch one corpus file — **opt-in**; hermetic by default.

    Refuses to touch the network unless explicitly allowed, so nothing
    in the test or CI path can grow an accidental network dependency:
    the vendored samples are the only corpus CI ever sees.

    Args:
        url: source URL (e.g. a SuiteSparse or DLMC matrix file).
        dest: local path to write; parent directories are created.
            An existing file is returned as-is without any network use.
        allow: ``True`` to permit the fetch; defaults to the
            ``$REPRO_CORPUS_ALLOW_DOWNLOAD=1`` environment opt-in.
        timeout: socket timeout in seconds.

    Returns:
        The local path.

    Raises:
        CorpusDownloadDisabled: when called without the opt-in.
    """
    dest = pathlib.Path(dest)
    if dest.is_file():
        return dest
    if allow is None:
        allow = os.environ.get("REPRO_CORPUS_ALLOW_DOWNLOAD") == "1"
    if not allow:
        raise CorpusDownloadDisabled(
            f"refusing to download {url}: the corpus layer is hermetic "
            f"by default (vendored samples only).  Set "
            f"REPRO_CORPUS_ALLOW_DOWNLOAD=1 (or pass allow=True) and "
            f"point REPRO_CORPUS_DIR at the download directory to opt "
            f"in.")
    import urllib.request
    dest.parent.mkdir(parents=True, exist_ok=True)
    tmp = dest.with_suffix(dest.suffix + ".part")
    with urllib.request.urlopen(url, timeout=timeout) as r:
        tmp.write_bytes(r.read())
    tmp.replace(dest)
    return dest


def load_corpus(root: Optional[os.PathLike] = None,
                groups: Optional[Sequence[str]] = None):
    """Load the active corpus as ``{name: COOMatrix}`` (group-filtered)."""
    out = {}
    for e in corpus_entries(root):
        if groups and e.group not in groups:
            continue
        out[e.name] = e.load()
    return out
