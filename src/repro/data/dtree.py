"""SpChar-style learned dispatch fallback: a dependency-free decision tree.

The analytic roofline ranks formats from first principles; SpChar
(Sgherzi et al., 2023, arXiv 2304.06944) shows that a small decision
tree over cheap structural features predicts the winning implementation
where analytic models are within noise of each other.  This module is
that fallback, deliberately minimal:

  * pure NumPy CART (Gini impurity, axis-aligned splits) — no sklearn,
    nothing the container doesn't already have;
  * features are a fixed, named subset of ``StructureReport.stats`` plus
    the dense width ``d`` (:data:`FEATURES`,
    :func:`features_from_report`);
  * the fitted tree persists as JSON next to the calibration store
    (:class:`DispatchTreeStore`), stamped with the feature schema and
    the kernel-registry version so a stale tree is refused exactly like
    a stale calibration;
  * every prediction carries its full decision path
    (:meth:`DecisionTree.decision_path`) so the dispatcher can record
    provenance in ``DispatchPlan`` the way ``ceiling_source`` records
    ceiling provenance.

The tree is *fitted* by ``tools/harvest_dispatch.py`` from measured
(structure features, per-format GFLOP/s) pairs over the matrix corpus,
and *consulted* by ``repro.sparse.dispatch.Dispatcher`` only when the
analytic top-two candidates are within a configurable margin — the
analytic model stays authoritative everywhere it is confident.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: The feature schema, in order.  All numeric; ``inf`` (hill alpha with
#: no detectable tail) is clamped to :data:`ALPHA_CAP` so splits stay
#: finite.  ``d`` is the dense operand width — the winning format is a
#: function of the (matrix, d) pair, not the matrix alone.
FEATURES: Tuple[str, ...] = (
    "log2_n", "log2_nnz", "avg_degree", "band_fraction", "alpha_hill",
    "degree_gini", "hub_dominance", "row_gini", "col_gini",
    "block_D", "block_z_emp", "block_fill", "d",
)

#: Finite stand-in for ``alpha_hill == inf`` ("no heavy tail").
ALPHA_CAP = 100.0


def features_from_report(report, d: int) -> np.ndarray:
    """Extract the :data:`FEATURES` vector from a ``StructureReport``.

    Args:
        report: ``repro.core.classify.StructureReport``.
        d: dense operand width of the dispatch decision.

    Returns:
        ``float64 [len(FEATURES)]`` in schema order.
    """
    s = report.stats
    raw = {
        "log2_n": np.log2(max(s.get("n", 1), 1)),
        "log2_nnz": np.log2(max(s.get("nnz", 1), 1)),
        "avg_degree": s.get("avg_degree", 0.0),
        "band_fraction": s.get("band_fraction", 0.0),
        "alpha_hill": min(s.get("alpha_hill", ALPHA_CAP), ALPHA_CAP),
        "degree_gini": s.get("degree_gini", 0.0),
        "hub_dominance": s.get("hub_dominance", 1.0),
        "row_gini": s.get("row_gini", 0.0),
        "col_gini": s.get("col_gini", 0.0),
        "block_D": s.get("block_D", 0.0),
        "block_z_emp": s.get("block_z_emp", 0.0),
        "block_fill": s.get("block_fill", 0.0),
        "d": float(d),
    }
    return np.array([float(raw[f]) for f in FEATURES], dtype=np.float64)


def _gini(counts: np.ndarray) -> float:
    total = counts.sum()
    if total == 0:
        return 0.0
    p = counts / total
    return float(1.0 - (p * p).sum())


@dataclasses.dataclass
class _Node:
    """One tree node; leaves carry ``label``, internals a split."""

    feature: Optional[int] = None     # FEATURES index (None = leaf)
    threshold: float = 0.0            # go left when x[f] <= threshold
    left: int = -1                    # child node ids
    right: int = -1
    label: Optional[str] = None       # majority class at this node
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)


class DecisionTree:
    """CART classifier over :data:`FEATURES`, JSON-serializable.

    Build with :meth:`fit` (or :meth:`from_json`); query with
    :meth:`predict` / :meth:`decision_path`.  The class list, node
    table, and feature schema round-trip losslessly through
    :meth:`to_json`, and :meth:`fingerprint` hashes that payload so a
    dispatcher cache key can tell two fitted trees apart.
    """

    def __init__(self, *, max_depth: int = 4, min_leaf: int = 2):
        """Create an unfitted tree with the given growth limits.

        Args:
            max_depth: maximum split depth (root = 0).
            min_leaf: minimum samples on each side of a split.
        """
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.features: Tuple[str, ...] = FEATURES
        self.nodes: List[_Node] = []

    # ------------------------------------------------------------- #
    # Fitting
    # ------------------------------------------------------------- #

    def fit(self, x: np.ndarray, y: Sequence[str]) -> "DecisionTree":
        """Fit on ``x [m, len(FEATURES)]`` and labels ``y [m]``.

        Returns ``self`` for chaining.  Raises ``ValueError`` on an
        empty or shape-mismatched training set.
        """
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(list(y), dtype=object)
        if x.ndim != 2 or x.shape[0] == 0 or x.shape[0] != y.shape[0]:
            raise ValueError(f"need matched non-empty x [m, f] / y [m], "
                             f"got {x.shape} vs {y.shape}")
        if x.shape[1] != len(self.features):
            raise ValueError(f"x has {x.shape[1]} features, schema has "
                             f"{len(self.features)}")
        self.classes_ = sorted(set(y))
        self.nodes = []
        self._grow(x, y, depth=0)
        return self

    def _counts(self, y: np.ndarray) -> Dict[str, int]:
        return {c: int((y == c).sum()) for c in self.classes_
                if (y == c).sum()}

    def _grow(self, x: np.ndarray, y: np.ndarray, depth: int) -> int:
        node_id = len(self.nodes)
        counts = self._counts(y)
        label = max(counts, key=counts.get)
        node = _Node(label=label, counts=counts)
        self.nodes.append(node)
        if depth >= self.max_depth or len(counts) <= 1 \
                or y.shape[0] < 2 * self.min_leaf:
            return node_id
        best = self._best_split(x, y)
        if best is None:
            return node_id
        f, thr = best
        mask = x[:, f] <= thr
        node.feature, node.threshold = f, thr
        node.left = self._grow(x[mask], y[mask], depth + 1)
        node.right = self._grow(x[~mask], y[~mask], depth + 1)
        return node_id

    def _best_split(self, x: np.ndarray,
                    y: np.ndarray) -> Optional[Tuple[int, float]]:
        class_ids = np.array([self.classes_.index(c) for c in y])
        parent_counts = np.bincount(class_ids, minlength=len(self.classes_))
        parent_gini = _gini(parent_counts)
        m = y.shape[0]
        best_gain, best = 1e-12, None
        for f in range(x.shape[1]):
            vals = np.unique(x[:, f])
            if vals.size < 2:
                continue
            for thr in (vals[:-1] + vals[1:]) / 2.0:
                mask = x[:, f] <= thr
                nl = int(mask.sum())
                if nl < self.min_leaf or m - nl < self.min_leaf:
                    continue
                gl = _gini(np.bincount(class_ids[mask],
                                       minlength=len(self.classes_)))
                gr = _gini(np.bincount(class_ids[~mask],
                                       minlength=len(self.classes_)))
                gain = parent_gini - (nl * gl + (m - nl) * gr) / m
                if gain > best_gain:
                    best_gain, best = gain, (f, float(thr))
        return best

    # ------------------------------------------------------------- #
    # Prediction
    # ------------------------------------------------------------- #

    def _walk(self, x: np.ndarray) -> List[int]:
        if not self.nodes:
            raise ValueError("tree is not fitted")
        path, node_id = [0], 0
        while self.nodes[node_id].feature is not None:
            node = self.nodes[node_id]
            node_id = node.left if x[node.feature] <= node.threshold \
                else node.right
            path.append(node_id)
        return path

    def predict(self, x: np.ndarray) -> str:
        """The label at the leaf ``x`` lands in."""
        return self.nodes[self._walk(np.asarray(x))[-1]].label

    def decision_path(self, x: np.ndarray) -> Tuple[str, ...]:
        """Human-readable split trail for ``x``, leaf included.

        Each element is ``"feature<=thr"`` / ``"feature>thr"`` for the
        branch taken, ending with ``"leaf:label(n=...)"`` — the
        provenance string the dispatcher stores in ``DispatchPlan``.
        """
        x = np.asarray(x)
        path = self._walk(x)
        out = []
        for node_id in path[:-1]:
            node = self.nodes[node_id]
            name = self.features[node.feature]
            taken = "<=" if x[node.feature] <= node.threshold else ">"
            out.append(f"{name}{taken}{node.threshold:.3g}")
        leaf = self.nodes[path[-1]]
        out.append(f"leaf:{leaf.label}(n={sum(leaf.counts.values())})")
        return tuple(out)

    # ------------------------------------------------------------- #
    # Serialization
    # ------------------------------------------------------------- #

    def to_json(self) -> dict:
        """The JSON payload (feature schema + node table + limits)."""
        return {
            "features": list(self.features),
            "max_depth": self.max_depth,
            "min_leaf": self.min_leaf,
            "classes": list(getattr(self, "classes_", [])),
            "nodes": [dataclasses.asdict(n) for n in self.nodes],
        }

    @classmethod
    def from_json(cls, payload: dict) -> "DecisionTree":
        """Rebuild a fitted tree from :meth:`to_json` output."""
        tree = cls(max_depth=int(payload.get("max_depth", 4)),
                   min_leaf=int(payload.get("min_leaf", 2)))
        tree.features = tuple(payload["features"])
        tree.classes_ = list(payload.get("classes", []))
        tree.nodes = [_Node(**n) for n in payload["nodes"]]
        return tree

    def fingerprint(self) -> str:
        """Stable short hash of the fitted tree (dispatch cache key part)."""
        blob = json.dumps(self.to_json(), sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()[:12]


class DispatchTreeStore:
    """Persistence for the fitted dispatch tree, beside the calibrations.

    Files live in the same root as
    ``repro.core.calibrate.CalibrationStore`` (``$REPRO_CALIBRATION_DIR``
    or ``~/.cache/repro/calibrations``) as
    ``dispatch_tree-<backend>.json`` — the tree, like a calibration,
    describes measured kernel behavior and is keyed by backend.  ``load``
    refuses payloads whose feature schema no longer matches
    :data:`FEATURES` or whose kernel-registry version predates the
    active one (formats the tree learned about may have changed).
    """

    def __init__(self, root: Optional[os.PathLike] = None):
        """Open (without touching the filesystem) the store at ``root``."""
        if root is None:
            root = os.environ.get("REPRO_CALIBRATION_DIR") or (
                pathlib.Path.home() / ".cache" / "repro" / "calibrations")
        self.root = pathlib.Path(root)

    def path_for(self, backend: str = "jax") -> pathlib.Path:
        """The JSON path holding ``backend``'s fitted tree."""
        return self.root / f"dispatch_tree-{backend}.json"

    def save(self, tree: DecisionTree, backend: str = "jax",
             meta: Optional[dict] = None) -> pathlib.Path:
        """Write the fitted tree (creating the root) and return the path."""
        from repro.kernels import registry
        self.root.mkdir(parents=True, exist_ok=True)
        payload = {"tree": tree.to_json(), "backend": backend,
                   "registry_version": registry.REGISTRY_VERSION,
                   "meta": dict(meta or {})}
        path = self.path_for(backend)
        path.write_text(json.dumps(payload, indent=2), encoding="utf-8")
        return path

    def load(self, backend: str = "jax") -> Optional[DecisionTree]:
        """Read the tree for ``backend``; ``None`` when absent or stale."""
        path = self.path_for(backend)
        if not path.is_file():
            return None
        try:
            payload = json.loads(path.read_text(encoding="utf-8"))
            tree = DecisionTree.from_json(payload["tree"])
        except (OSError, KeyError, TypeError, json.JSONDecodeError):
            return None
        if tuple(tree.features) != FEATURES:
            return None                  # schema drift: refuse silently
        if payload.get("backend", "jax") != backend:
            return None
        from repro.kernels import registry
        if int(payload.get("registry_version", 0)) \
                < registry.REGISTRY_VERSION:
            return None                  # learned about retired kernels
        return tree
