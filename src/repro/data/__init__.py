"""repro.data: dataset pipeline, matrix corpus, and learned dispatch.

``pipeline`` feeds the training stack; ``corpus`` loads real-matrix
files (``.smtx`` / Matrix Market) with a vendored offline sample set;
``dtree`` is the SpChar-style decision-tree dispatch fallback fitted
from corpus harvests.
"""
from repro.data.corpus import (          # noqa: F401
    CorpusDownloadDisabled,
    CorpusEntry,
    corpus_entries,
    load_corpus,
    load_matrix,
    load_mtx,
    load_smtx,
    vendored_entries,
    write_mtx,
    write_smtx,
)
from repro.data.dtree import (           # noqa: F401
    FEATURES,
    DecisionTree,
    DispatchTreeStore,
    features_from_report,
)
