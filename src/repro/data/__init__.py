"""repro.data"""
