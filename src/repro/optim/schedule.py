"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, warmup_steps: int = 500,
                       total_steps: int = 100_000,
                       min_ratio: float = 0.1) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_ratio. Returns a scale in
    (0, 1] multiplied into the base lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    progress = (step - warmup_steps) / jnp.maximum(
        total_steps - warmup_steps, 1)
    progress = jnp.clip(progress, 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(
        jnp.pi * progress))
    return jnp.where(step < warmup_steps, warm, cos)
