"""Gradient compression for cross-pod data parallelism.

At pod scale the slowest collective hop is the inter-pod one (DCN or
long-haul ICI).  We compress the *cross-pod* gradient all-reduce to int8
with per-tensor scales and an error-feedback residual so compression noise
is unbiased over steps (1-bit Adam lineage; here 8-bit symmetric).

Usage (trainer): grads are psum'd over the in-pod data axis at full
precision (cheap links), then the pod-axis reduction runs through
``compressed_psum`` under shard_map.  Error feedback state lives next to
the optimizer state and is checkpointed with it.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grad(g: jnp.ndarray,
                  residual: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                  jnp.ndarray]:
    """Error-feedback int8 compression of one gradient tensor.

    Returns (q, scale, new_residual): q*scale + new_residual == g + residual.
    """
    corrected = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(corrected)
    new_residual = corrected - dequantize_int8(q, scale)
    return q, scale, new_residual


def compressed_psum(g: jnp.ndarray, residual: jnp.ndarray,
                    axis_name: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 all-reduce over ``axis_name`` inside shard_map.

    The int8 payload is what crosses the slow axis (8x less than f32 and
    4x less than bf16); scales are psum'd separately (scalar traffic).
    Averaging happens in f32 after dequantization.
    """
    q, scale, new_residual = compress_grad(g, residual)
    n = jax.lax.psum(1, axis_name)
    # int8 sums can overflow int8: widen lanes to int32 for the reduction;
    # the wire format stays 8-bit per element (documented approximation of
    # a ring all-reduce with int8 segments + f32 accumulators).
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)
    out = q_sum.astype(jnp.float32) * scale_max / n
    return out.astype(g.dtype), new_residual


def init_residuals(grads) -> Dict:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
