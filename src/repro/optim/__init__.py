"""repro.optim"""
