"""Sharded AdamW with decoupled weight decay and global-norm clipping.

Optimizer state lives in the same sharding as the parameters (the trainer's
pjit in_shardings make every state leaf inherit the param PartitionSpec), so
memory scales 1/chips.  No optax dependency — the update is ~30 lines and we
need custom hooks (compression, multi-dtype state) anyway.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"    # "bfloat16" => low-memory variant


def init_state(params, cfg: AdamWConfig) -> Dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0)))


def apply_updates(params, grads, state: Dict, cfg: AdamWConfig,
                  lr_scale: jnp.ndarray = 1.0) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu32 / c1
        vhat = nu32 / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * lr_scale * step
        return new_p.astype(p.dtype), mu32.astype(dt), nu32.astype(dt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm}
